"""Flashtrace: host-side span tracing, counters/gauges, and
Perfetto/Prometheus export for the serving stack.

Off by default; ``enable_tracing()`` installs a ring-buffered
:class:`~repro.obs.trace.SpanRecorder` that the instrumentation points in
core/schedule, core/engine, core/generic, the serving backends, and the
frontend write into.  See trace.py for the never-enters-jit contract and
export.py for the serializers.  README "Observability" documents the
span taxonomy.
"""

from repro.obs.export import (perfetto_trace, prometheus_text,  # noqa: F401
                              write_metrics_text, write_trace_json)
from repro.obs.trace import (SpanRecorder, active_recorder,  # noqa: F401
                             disable_tracing, enable_tracing, perf_now)
