"""Flashtrace span recorder: host-side tracing + metrics, off by default.

One module-global :data:`RECORDER` is the entire enable/disable switch.
Instrumentation sites follow one pattern::

    rec = trace.RECORDER
    if rec is None:
        return fn(...)                  # disabled: one attr load + None test
    t0 = trace.perf_now()
    out = fn(...)
    rec.add_span("engine.decode_chunk", "engine", t0, trace.perf_now(), ...)

so the disabled path allocates nothing and never branches into recorder
code.  The recorder itself preallocates fixed-capacity rings for spans /
instants / counter samples (oldest events are overwritten, drop counts
kept), so a long serve cannot grow host memory without bound.

THE HARD CONTRACT (enforced by flashcheck FC007 + the jaxpr pass): this
module is called only from the HOST side of the dispatch boundary —
the ``decode_chunk``/``server_chunk``/``prefill*`` wrappers, the serving
backends, and the frontend.  Nothing here is ever reachable from a traced
``*_impl`` body, no ``io_callback``/``pure_callback`` is ever emitted,
and the jaxpr of every chunk program is bitwise independent of whether
tracing is on.  Tracing on vs off therefore yields identical greedy
streams; spans measure host-visible time only (an async dispatch span is
the host cost of launching the program, not device compute — the
readback/collect span is where device time surfaces).

Perfetto / Prometheus serialization lives in :mod:`repro.obs.export`.
"""

from __future__ import annotations

import time
from typing import Any

__all__ = [
    "SpanRecorder", "RECORDER", "enable_tracing", "disable_tracing",
    "active_recorder", "perf_now",
]


def perf_now() -> float:
    """Monotonic wall time (seconds) — the one clock every span uses."""
    return time.perf_counter()


def _label_key(labels: dict[str, Any]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class SpanRecorder:
    """Ring-buffered span/instant/sample store + counter/gauge maps.

    The host serving loop is single-threaded (dispatch-ahead pipelining
    interleaves on one thread), so no locking: writes are index-bump +
    slot-assign into preallocated lists.
    """

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.t_zero = perf_now()  # export time base (ts=0 in the trace)
        self._spans: list = [None] * self.capacity
        self._n_spans = 0  # monotone; ring index = n % capacity
        self._instants: list = [None] * self.capacity
        self._n_instants = 0
        self._samples: list = [None] * self.capacity
        self._n_samples = 0
        # (name, ((label, value), ...)) -> float
        self.counters: dict[tuple[str, tuple], float] = {}
        self.gauges: dict[tuple[str, tuple], float] = {}

    # --------------------------------------------------------------- events
    def add_span(self, name: str, track: str, t0: float, t1: float,
                 args: dict | None = None) -> None:
        """Record a completed [t0, t1] span (perf_now() values) on a track
        (one Perfetto thread row per track name)."""
        self._spans[self._n_spans % self.capacity] = (name, track, t0, t1,
                                                      args)
        self._n_spans += 1

    def add_instant(self, name: str, track: str, t: float,
                    args: dict | None = None) -> None:
        """Record a point event (Perfetto 'i' phase) — evictions, rejects."""
        self._instants[self._n_instants % self.capacity] = (name, track, t,
                                                            args)
        self._n_instants += 1

    def add_sample(self, name: str, t: float, value: float) -> None:
        """Record a time series point (Perfetto 'C' counter track) —
        queue depth, live slots."""
        self._samples[self._n_samples % self.capacity] = (name, t,
                                                          float(value))
        self._n_samples += 1

    # ------------------------------------------------------ counters/gauges
    def inc_counter(self, name: str, n: float = 1.0, **labels) -> None:
        key = (name, _label_key(labels))
        self.counters[key] = self.counters.get(key, 0.0) + n

    def set_gauge(self, name: str, value: float, **labels) -> None:
        self.gauges[(name, _label_key(labels))] = float(value)

    # ---------------------------------------------------------------- views
    def _ring_view(self, ring: list, n: int) -> list:
        if n <= self.capacity:
            return [e for e in ring[:n]]
        i = n % self.capacity
        return ring[i:] + ring[:i]  # oldest survivor first

    def spans_view(self) -> list:
        """Recorded spans, oldest first: (name, track, t0, t1, args)."""
        return self._ring_view(self._spans, self._n_spans)

    def instants_view(self) -> list:
        return self._ring_view(self._instants, self._n_instants)

    def samples_view(self) -> list:
        return self._ring_view(self._samples, self._n_samples)

    def counters_view(self) -> dict[str, float]:
        """Flat {'name{k="v",...}': value} map (Prometheus-style keys)."""
        return {_format_key(k): v for k, v in sorted(self.counters.items())}

    def gauges_view(self) -> dict[str, float]:
        return {_format_key(k): v for k, v in sorted(self.gauges.items())}

    @property
    def dropped(self) -> dict[str, int]:
        """Events overwritten by ring wrap-around, per stream."""
        cap = self.capacity
        return {"spans": max(0, self._n_spans - cap),
                "instants": max(0, self._n_instants - cap),
                "samples": max(0, self._n_samples - cap)}


def _format_key(key: tuple[str, tuple]) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


# The switch.  None = tracing disabled (the default); instrumentation
# sites read this once per call and fall through when it is None.
RECORDER: SpanRecorder | None = None


def enable_tracing(capacity: int = 65536) -> SpanRecorder:
    """Install a fresh recorder (discarding any previous one) and return it."""
    global RECORDER
    RECORDER = SpanRecorder(capacity)
    return RECORDER


def disable_tracing() -> None:
    """Remove the recorder: instrumentation reverts to the zero-cost path."""
    global RECORDER
    RECORDER = None


def active_recorder() -> SpanRecorder | None:
    """The installed recorder, or None when tracing is off."""
    return RECORDER
