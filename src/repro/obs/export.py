"""Flashtrace exporters: Chrome/Perfetto ``trace.json`` + Prometheus text.

Perfetto: the Trace Event JSON format (``{"traceEvents": [...]}``) —
open at https://ui.perfetto.dev (or chrome://tracing).  Every recorder
*track* becomes one named thread row (``"M"`` thread_name metadata +
``"X"`` complete events with µs timestamps relative to the recorder's
enable time); recorder *samples* become ``"C"`` counter tracks and
*instants* become ``"i"`` events.

Prometheus: plain text exposition — counters as ``*_total``-style
monotone values, gauges as-is, with recorder label sets rendered in
standard ``name{k="v"}`` form.  This is a snapshot writer, not a live
scrape endpoint: serve.py writes it next to the trace at exit.
"""

from __future__ import annotations

import json
import os
import re

from repro.obs.trace import SpanRecorder

__all__ = ["perfetto_trace", "prometheus_text", "write_trace_json",
           "write_metrics_text"]

_PID = 1  # single-process trace: one pid, one tid per track


def _track_tids(rec: SpanRecorder) -> dict[str, int]:
    tracks = []
    for name, track, *_ in rec.spans_view():
        if track not in tracks:
            tracks.append(track)
    for name, track, *_ in rec.instants_view():
        if track not in tracks:
            tracks.append(track)
    return {t: i + 1 for i, t in enumerate(tracks)}


def perfetto_trace(rec: SpanRecorder) -> dict:
    """Serialize a recorder to a Chrome/Perfetto trace-event dict."""
    tids = _track_tids(rec)
    us = 1e6
    t0 = rec.t_zero
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": _PID,
        "args": {"name": "flashtrace"},
    }]
    for track, tid in tids.items():
        events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                       "tid": tid, "args": {"name": track}})
    for name, track, s0, s1, args in rec.spans_view():
        ev = {"name": name, "ph": "X", "pid": _PID, "tid": tids[track],
              "ts": (s0 - t0) * us, "dur": max(0.0, (s1 - s0) * us)}
        if args:
            ev["args"] = args
        events.append(ev)
    for name, track, t, args in rec.instants_view():
        ev = {"name": name, "ph": "i", "s": "t", "pid": _PID,
              "tid": tids[track], "ts": (t - t0) * us}
        if args:
            ev["args"] = args
        events.append(ev)
    for name, t, value in rec.samples_view():
        events.append({"name": name, "ph": "C", "pid": _PID,
                       "ts": (t - t0) * us, "args": {"value": value}})
    return {"traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped": rec.dropped}}


_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(key: str) -> str:
    """Sanitize a counter key: dots -> underscores in the metric name,
    label block (if any) passed through untouched."""
    name, brace, labels = key.partition("{")
    return _NAME_OK.sub("_", name) + brace + labels


def prometheus_text(rec: SpanRecorder) -> str:
    """Render counters + gauges in Prometheus text exposition format."""
    lines: list[str] = []
    typed: set[str] = set()

    def emit(kind: str, flat: dict[str, float]):
        for key, value in flat.items():
            full = _prom_name(key)
            base = full.partition("{")[0]
            if base not in typed:
                typed.add(base)
                lines.append(f"# TYPE {base} {kind}")
            lines.append(f"{full} {value:g}")

    emit("counter", rec.counters_view())
    emit("gauge", rec.gauges_view())
    for stream, n in rec.dropped.items():
        base = f"flashtrace_dropped_events{{stream=\"{stream}\"}}"
        if "flashtrace_dropped_events" not in typed:
            typed.add("flashtrace_dropped_events")
            lines.append("# TYPE flashtrace_dropped_events counter")
        lines.append(f"{base} {n}")
    return "\n".join(lines) + "\n"


def write_trace_json(rec: SpanRecorder, path: str) -> str:
    with open(path, "w") as f:
        json.dump(perfetto_trace(rec), f, indent=1)
        f.write("\n")
    return os.path.abspath(path)


def write_metrics_text(rec: SpanRecorder, path: str) -> str:
    with open(path, "w") as f:
        f.write(prometheus_text(rec))
    return os.path.abspath(path)
