from repro.train_loop.loop import Trainer, make_train_step  # noqa: F401
