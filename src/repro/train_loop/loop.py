"""Training loop: jitted (loss, grad, AdamW) step + host-side driver.

``make_train_step`` builds the pure step function used everywhere — the CPU
driver jits it directly; the launcher (repro/launch/train.py) wraps the same
function in pjit with mesh shardings; the dry-run lowers it with
ShapeDtypeStructs.  One function, three consumers — no divergence.
"""

from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.lm import LM
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(model: LM, opt_cfg: AdamWConfig) -> Callable:
    """Builds the train step; ``cfg.train_microbatch > 1`` enables gradient
    accumulation (scan over microbatches) — the standard memory/throughput
    trade for the biggest configs (jamba-398B, deepseek-v3) whose per-layer
    backward working set exceeds HBM at full per-chip batch."""
    micro = getattr(model.cfg, "train_microbatch", 1)

    def split_mb(batch):
        from repro.models.components import sharding_ctx

        dp, _ = sharding_ctx()
        out = {}
        for k, v in batch.items():
            if k == "pos3":  # (3, B, T) — batch on axis 1
                r = v.reshape(3, micro, -1, v.shape[-1]).transpose(1, 0, 2, 3)
                spec = (None, None, dp)
            else:
                r = v.reshape((micro, v.shape[0] // micro) + v.shape[1:])
                spec = (None, dp)
            if dp is not None:
                from jax.sharding import PartitionSpec as P

                r = jax.lax.with_sharding_constraint(
                    r, P(*spec, *([None] * (r.ndim - len(spec)))))
            out[k] = r
        return out

    def train_step(params, opt_state, batch):
        if micro <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            def body(carry, mb):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(model.loss)(params, mb)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, g_acc, g)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), split_mb(batch))
            loss = loss / micro
            grads = jax.tree.map(lambda g: g / micro, grads)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: LM) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch)

    return eval_step


class Trainer:
    """Single-process driver (CPU tests / examples).  Multi-pod launch lives
    in repro/launch/train.py and reuses make_train_step under pjit."""

    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                 seed: int = 0):
        self.cfg = cfg
        self.model = LM(cfg)
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.opt_state = adamw_init(self.params)
        self._step = jax.jit(make_train_step(self.model, self.opt_cfg))

    def fit(self, dataset, n_steps: int, *, log_every: int = 10,
            ckpt_dir: str | None = None, ckpt_every: int = 0,
            log_fn=print) -> list[dict]:
        history = []
        t0 = time.perf_counter()
        for step in range(n_steps):
            batch = dataset.batch(step)
            self.params, self.opt_state, metrics = self._step(
                self.params, self.opt_state, batch)
            if step % log_every == 0 or step == n_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.perf_counter() - t0
                history.append(m)
                log_fn(f"step {step:5d}  loss {m['loss']:.4f}  "
                       f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.3f}")
            if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
                from repro.checkpoint import save_checkpoint

                save_checkpoint(ckpt_dir, step + 1,
                                {"params": self.params, "opt": self.opt_state})
        return history
