"""flashcheck — AST+jaxpr contract analyzer for the Flash-Inference repo.

Enforces the serving-stack invariants that shipped PRs learned the hard
way (see README "Static contracts" and each rule's docstring in
:mod:`repro.staticcheck.rules`):

  FC001 use-after-donate            FC004 lax.cond in hot dispatch
  FC002 mixed-dtype slice starts    FC005 unbounded jit caches
  FC003 dot/einsum in mixer path    FC006 import-scope config toggles
  FC007 host callbacks / repro.obs reachable from traced bodies

plus a jaxpr pass (:mod:`repro.staticcheck.jaxpr_pass`) that traces the
registered hot entry points and verifies donation aliasing, cond-free
batched dispatch, and one-rng-split-per-step from the traced program.

Run: ``python -m repro.staticcheck [src tests benchmarks]``.
"""

from .cli import analyze, main
from .config import Config, Suppression, load_config
from .findings import ERROR, WARN, Finding, Report
from .rules import Module, run_rules

__all__ = [
    "ERROR", "WARN", "Config", "Finding", "Module", "Report",
    "Suppression", "analyze", "load_config", "main", "run_rules",
]
