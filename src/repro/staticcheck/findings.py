"""Structured findings for the flashcheck contract analyzer.

A finding pins one violation of a repo contract to a (file, line) and
carries the rule id, a one-line message, and a fix hint — enough for a
developer to act without re-deriving the contract from CHANGES.md.  The
same records serialize to the ``--json`` report so finding counts can be
pinned like BENCH artifacts.
"""

from __future__ import annotations

from dataclasses import dataclass, field


# Severities: "error" findings fail the run; "warn" findings fail only
# under --fail-on-warn (the CI lint leg runs with it, so the distinction
# only matters for local incremental runs).
ERROR = "error"
WARN = "warn"


@dataclass(frozen=True)
class Finding:
    rule: str            # "FC001" .. "FC007" or "JX..." for jaxpr checks
    path: str            # repo-relative posix path
    line: int            # 1-based
    message: str
    hint: str = ""
    symbol: str = ""     # enclosing function/method name ("" = module scope)
    severity: str = ERROR
    suppressed_by: str = ""  # reason from staticcheck.toml, "" = live

    @property
    def suppressed(self) -> bool:
        return bool(self.suppressed_by)

    def render(self) -> str:
        where = f"{self.path}:{self.line}"
        sym = f" [{self.symbol}]" if self.symbol else ""
        sup = f"  (suppressed: {self.suppressed_by})" if self.suppressed else ""
        hint = f"\n    hint: {self.hint}" if self.hint and not self.suppressed else ""
        return f"{where}: {self.rule} {self.severity}{sym}: {self.message}{sup}{hint}"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule, "path": self.path, "line": self.line,
            "symbol": self.symbol, "severity": self.severity,
            "message": self.message, "hint": self.hint,
            "suppressed": self.suppressed, "suppressed_by": self.suppressed_by,
        }


@dataclass
class Report:
    """One analyzer run: AST findings + jaxpr entry-point verdicts."""

    findings: list[Finding] = field(default_factory=list)
    jaxpr: list[dict] = field(default_factory=list)  # per-entry-point verdicts
    files_scanned: int = 0

    def live(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    def failed(self, fail_on_warn: bool) -> bool:
        sev = {ERROR} if not fail_on_warn else {ERROR, WARN}
        if any(f.severity in sev for f in self.live()):
            return True
        return any(not e["ok"] for e in self.jaxpr)

    def counts(self) -> dict:
        by_rule: dict[str, int] = {}
        for f in self.live():
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        return {
            "files_scanned": self.files_scanned,
            "findings": len(self.live()),
            "suppressed": sum(1 for f in self.findings if f.suppressed),
            "by_rule": dict(sorted(by_rule.items())),
            "jaxpr_entry_points": len(self.jaxpr),
            "jaxpr_failures": sum(1 for e in self.jaxpr if not e["ok"]),
        }

    def to_dict(self) -> dict:
        return {
            "tool": "flashcheck",
            "counts": self.counts(),
            "findings": [f.to_dict() for f in sorted(
                self.findings, key=lambda f: (f.path, f.line, f.rule))],
            "jaxpr": self.jaxpr,
        }
