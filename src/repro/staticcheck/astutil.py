"""Shared AST machinery for the flashcheck rules.

Nothing here imports jax: the AST pass must stay runnable (and fast) in
any environment, including pre-commit hooks and docs builds.  The
heuristics are deliberately repo-shaped — they encode how THIS codebase
writes traced code (per-slot position vectors, ``starts()`` helpers,
``self._jit_*`` dispatch tables), not a general-purpose type system.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field


# --------------------------------------------------------------- dotted names
def dotted_name(node: ast.AST) -> str | None:
    """"x", "self.state", "eng.engine.state" for Name/Attribute chains
    (None for anything else — calls, subscripts, literals)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_segment(name: str | None) -> str | None:
    return None if name is None else name.rsplit(".", 1)[-1]


def callee_names(call: ast.Call) -> list[str]:
    """Candidate dotted callee names of a call.  Ternary callees — the
    repo's ``(self._jit_red if jitted else self._red_pass)(...)`` idiom —
    contribute both branches."""
    def of(expr: ast.AST) -> list[str]:
        if isinstance(expr, ast.IfExp):
            return of(expr.body) + of(expr.orelse)
        d = dotted_name(expr)
        return [d] if d else []
    return of(call.func)


def assigned_names(stmt: ast.stmt) -> set[str]:
    """Dotted names bound by an assignment-like statement (tuple targets
    flattened; starred/subscript targets contribute their base name)."""
    targets: list[ast.expr] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.NamedExpr)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    out: set[str] = set()

    def add(t: ast.expr) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                add(e)
        elif isinstance(t, ast.Starred):
            add(t.value)
        elif isinstance(t, ast.Subscript):
            d = dotted_name(t.value)
            if d:
                out.add(d)
        else:
            d = dotted_name(t)
            if d:
                out.add(d)
    for t in targets:
        add(t)
    return out


# ------------------------------------------------------------ function index
@dataclass
class FuncInfo:
    name: str
    qualname: str          # Class.method for methods
    node: ast.AST          # FunctionDef | AsyncFunctionDef
    path: str              # repo-relative file


def index_functions(tree: ast.Module, path: str) -> list[FuncInfo]:
    out: list[FuncInfo] = []

    def walk(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}" if prefix else child.name
                out.append(FuncInfo(child.name, qual, child, path))
                walk(child, qual + ".")
            elif isinstance(child, ast.ClassDef):
                walk(child, (f"{prefix}{child.name}" if prefix
                             else child.name) + ".")
            else:
                walk(child, prefix)
    walk(tree, "")
    return out


def enclosing_stmt(func: ast.AST, target: ast.AST) -> ast.stmt | None:
    """Smallest statement of ``func``'s body tree containing ``target``."""
    best: ast.stmt | None = None

    def walk(node: ast.AST) -> bool:
        found = node is target
        for child in ast.iter_child_nodes(node):
            found = walk(child) or found
        if found and isinstance(node, ast.stmt):
            nonlocal best
            if best is None:
                best = node
        return found
    walk(func)
    return best


def enclosing_loops(func: ast.AST, stmt: ast.stmt) -> list[ast.stmt]:
    """Innermost-first For/While statements of ``func`` containing ``stmt``."""
    chain: list[ast.stmt] = []

    def walk(node: ast.AST, loops: list[ast.stmt]) -> None:
        for child in ast.iter_child_nodes(node):
            nxt = loops + [child] if isinstance(
                child, (ast.For, ast.While, ast.AsyncFor)) else loops
            if child is stmt:
                nonlocal chain
                chain = list(reversed(nxt))
                return
            walk(child, nxt)
    walk(func, [])
    return chain


def loads_of(func: ast.AST, name: str) -> list[ast.AST]:
    """Load-context reads of dotted ``name`` (or a deeper attribute of it)
    anywhere in ``func``, including lambdas/comprehensions."""
    hits: list[ast.AST] = []
    for node in ast.walk(func):
        if isinstance(node, (ast.Name, ast.Attribute)) and isinstance(
                getattr(node, "ctx", None), ast.Load):
            d = dotted_name(node)
            if d and (d == name or d.startswith(name + ".")):
                hits.append(node)
    # Drop reads nested inside a larger matching chain (state.a reports once)
    spans = {(h.lineno, h.col_offset) for h in hits}
    return [h for h in hits
            if not any((h.lineno, c) in spans
                       for c in range(h.col_offset - 64, h.col_offset))
            or True]  # keep all; duplicates are collapsed at finding level


# --------------------------------------------------------- taint-lite (FC002)
_HOST_CALLS = {"int", "len", "range", "min", "max", "enumerate", "zip",
               "ceil_pow2", "largest_pow2_divisor"}
_HOST_ANNOT = {"int", "bool", "str", "float"}


class TaintLite:
    """Which local names in a function MAY hold traced values.

    Seeds: every parameter not annotated as a Python scalar (self/cls and
    ``int``/``str``-annotated params are host).  Propagation: a name
    assigned from an expression mentioning a suspect becomes suspect;
    ``.shape`` unpacking, ``int()``/``len()``/``range()`` results, and
    loop indices over ``range()`` are host.  Two linear passes make
    simple forward chains converge; this is a heuristic, not an
    inference engine — fixture tests pin exactly what it must catch.
    """

    def __init__(self, func: ast.AST):
        self.suspect: set[str] = set()
        args = getattr(func, "args", None)
        if args is not None:
            all_args = (list(args.posonlyargs) + list(args.args)
                        + list(args.kwonlyargs))
            for i, a in enumerate(all_args):
                if i == 0 and a.arg in ("self", "cls"):
                    continue
                ann = a.annotation
                ann_name = last_segment(dotted_name(ann)) if ann else None
                if isinstance(ann, ast.Constant):
                    ann_name = str(ann.value)
                if ann_name in _HOST_ANNOT:
                    continue
                self.suspect.add(a.arg)
        for _ in range(2):
            for node in ast.walk(func):
                if isinstance(node, ast.Assign) and len(node.targets) >= 1:
                    tainted = self.expr_suspect(node.value)
                    for t in node.targets:
                        self._mark(t, tainted, node.value)
                elif isinstance(node, (ast.For, ast.AsyncFor)):
                    tainted = self.expr_suspect(node.iter)
                    self._mark(node.target, tainted, node.iter)

    def _mark(self, target: ast.expr, tainted: bool, value: ast.expr) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            # ``B, P, _ = x.shape`` unpacks host ints even from traced x
            if self._is_shape(value):
                tainted = False
            for e in target.elts:
                self._mark(e, tainted, value)
            return
        d = dotted_name(target)
        if d is None or "." in d:
            return  # attribute targets don't shadow locals
        if tainted:
            self.suspect.add(d)
        else:
            self.suspect.discard(d)

    @staticmethod
    def _is_shape(value: ast.expr) -> bool:
        return (isinstance(value, ast.Attribute) and value.attr == "shape")

    def expr_suspect(self, expr: ast.expr | None) -> bool:
        """MAY this expression be traced?  Casts/host calls launder."""
        if expr is None or isinstance(expr, ast.Constant):
            return False
        if isinstance(expr, ast.Call):
            fn = last_segment(dotted_name(expr.func))
            if fn in _HOST_CALLS:
                return False
            if fn in ("asarray", "astype", "full", "array", "int32", "int64"):
                # an explicit jnp cast is the FC002 FIX idiom — not a mix
                return True  # still traced, but see literal-mix logic below
            return False  # unknown calls: host by default (low-FP bias)
        if isinstance(expr, ast.Attribute):
            if expr.attr in ("shape", "ndim", "size", "dtype"):
                return False
            return False  # self.x / spec.y are host scalars in this repo
        if isinstance(expr, ast.Name):
            return expr.id in self.suspect
        if isinstance(expr, ast.Subscript):
            if self._is_shape(expr.value):
                return False
            return self.expr_suspect(expr.value)
        if isinstance(expr, ast.BinOp):
            return (self.expr_suspect(expr.left)
                    or self.expr_suspect(expr.right))
        if isinstance(expr, ast.UnaryOp):
            return self.expr_suspect(expr.operand)
        if isinstance(expr, ast.IfExp):
            return (self.expr_suspect(expr.body)
                    or self.expr_suspect(expr.orelse))
        if isinstance(expr, (ast.Tuple, ast.List)):
            return any(self.expr_suspect(e) for e in expr.elts)
        return False


# ---------------------------------------------------------------- call graph
@dataclass
class CallGraph:
    """Name-based reachability over every function defined in the scanned
    file set.  An edge A -> B exists when A's body mentions (Load) a name
    whose last segment is B's simple name — this over-approximates calls
    (covers ternaries, functools.partial, callables passed as values),
    which is the right bias for a reachability *ban*."""

    funcs: dict[str, list[FuncInfo]] = field(default_factory=dict)
    edges: dict[str, set[str]] = field(default_factory=dict)

    @classmethod
    def build(cls, modules: list[tuple[str, ast.Module]]) -> "CallGraph":
        g = cls()
        infos: list[FuncInfo] = []
        for path, tree in modules:
            infos.extend(index_functions(tree, path))
        for fi in infos:
            g.funcs.setdefault(fi.name, []).append(fi)
        names = set(g.funcs)
        for fi in infos:
            refs: set[str] = set()
            for node in ast.walk(fi.node):
                if isinstance(node, (ast.Name, ast.Attribute)) and isinstance(
                        getattr(node, "ctx", None), ast.Load):
                    seg = last_segment(dotted_name(node))
                    if seg in names and seg != fi.name:
                        refs.add(seg)
            g.edges.setdefault(fi.name, set()).update(refs)
        return g

    def reach(self, roots: list[str], blocked: set[str]) -> dict[str, list[str]]:
        """name -> call chain (root..name) for every function reachable from
        ``roots`` without entering ``blocked`` nodes."""
        out: dict[str, list[str]] = {}
        stack = [(r, [r]) for r in roots if r in self.funcs]
        while stack:
            name, chain = stack.pop()
            if name in out or name in blocked:
                continue
            out[name] = chain
            for nxt in sorted(self.edges.get(name, ())):
                if nxt not in out and nxt not in blocked:
                    stack.append((nxt, chain + [nxt]))
        return out
