"""Jaxpr-level contract verification of the registered hot entry points.

The AST rules catch the *source* shape of a violation; this pass checks
the contracts where they actually bind — in the traced program:

  * **donation aliasing** — every donated state leaf must alias an output
    buffer in the lowered StableHLO (``tf.aliasing_output``).  Donation
    silently degrades to a copy when output shardings or shapes drift
    from the input, so counting the attrs is the only reliable check.
  * **cond-free batched dispatch** — no ``cond`` primitive anywhere in
    the jaxpr of a batched-dispatch chunk (the retired reference ladder
    must remain the ONLY source of ``cond``; it is traced here too, as a
    positive control that the counter sees conds at all).
  * **one rng split per emitted step** — a K-step chunk must contain
    exactly K ``random_split`` equations: a missing split reuses a key
    across steps (correlated sampling), an extra one desyncs the
    chunked path from the per-step reference stream.
  * **callback-free + trace invariance** — no host-callback primitive
    (``pure_callback``/``io_callback``/``debug_callback``) in any hot
    program, and the chunk jaxpr is character-identical with flashtrace
    enabled vs disabled (FC007's runtime half: obs never enters a traced
    program).

Entry points registered (the serving hot surface):

  FlashEngine.decode_chunk         (lockstep fused chunk)
  FlashEngine.server_chunk         (per-slot fused chunk, batched)
  FlashEngine.prefill_slot         (admission prefill)
  GenericFlashEngine.server_chunk  (generic "and Beyond" serving chunk)
  GenericFlashEngine.prefill_slot

Each entry is traced with tiny-model abstract inputs under the current
device config; with >= 4 devices the LCSM engine is additionally built on
a 4-way data mesh (donation and cond behavior are mesh-sensitive — the
whole point of the batched dispatch refactor).
"""

from __future__ import annotations

import functools

K_STEPS = 4          # fused steps per traced chunk
_SIDES = (1, 2, 1, 0)  # a valid lockstep segment: lowbit tiles + final step


def _count_primitives(jaxpr, names: set[str]) -> dict[str, int]:
    """Recursive primitive census over a (Closed)Jaxpr, descending into
    every sub-jaxpr carried in eqn params (pjit bodies, cond branches,
    scan/while carries)."""
    counts = {n: 0 for n in names}

    def visit(jx) -> None:
        inner = getattr(jx, "jaxpr", jx)  # ClosedJaxpr -> Jaxpr
        for eq in inner.eqns:
            name = eq.primitive.name
            if name in counts:
                counts[name] += 1
            for val in eq.params.values():
                for sub in _subjaxprs(val):
                    visit(sub)
    visit(jaxpr)
    return counts


def _subjaxprs(val):
    import jax.core as core
    if isinstance(val, (core.ClosedJaxpr, core.Jaxpr)):
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _subjaxprs(v)


def _check(name: str, expected, actual) -> dict:
    return {"name": name, "expected": expected, "actual": actual,
            "ok": expected == actual}


def _verdict(entry: str, fn, args, *, n_donated: int, splits: int,
             mesh: str | None, extra_checks=()) -> dict:
    """Trace + lower ``fn`` on ``args`` and evaluate the three contracts."""
    import jax

    jaxpr = jax.make_jaxpr(fn)(*args)
    prims = _count_primitives(jaxpr, {"cond", "random_split", "pure_callback",
                                      "io_callback", "debug_callback"})
    txt = fn.lower(*args).as_text()
    # Unsharded lowerings resolve donation to input/output aliases
    # (tf.aliasing_output); sharded lowerings defer the pairing to the
    # compiler and mark donors instead (jax.buffer_donor).  Either way
    # every donated state leaf must carry exactly one marker.
    checks = [
        _check("donation_aliasing", n_donated,
               txt.count("tf.aliasing_output")
               + txt.count("jax.buffer_donor")),
        _check("cond_free", 0, prims["cond"]),
        _check("one_split_per_step", splits, prims["random_split"]),
        # Flashtrace hard contract (FC007's runtime half): no host-callback
        # primitive in any hot program — a callback would stall the async
        # dispatch pipeline and make the program depend on host state.
        _check("callback_free", 0, prims["pure_callback"]
               + prims["io_callback"] + prims["debug_callback"]),
    ]
    checks.extend(extra_checks)
    return {"entry": entry, "devices": jax.device_count(), "mesh": mesh,
            "checks": checks, "ok": all(c["ok"] for c in checks)}


def _tiny_flash_engine(mesh=None, gray_impl="xla"):
    import jax

    from repro.core.engine import FlashEngine
    from repro.models.synthetic_lcsm import SyntheticLCSM

    model = SyntheticLCSM(n_levels=2, d_model=8)
    params = model.init(jax.random.PRNGKey(0))
    kw = {"mesh": mesh} if mesh is not None else {}
    return FlashEngine(model, params, batch=4, gen_max=16, prompt_max=4,
                       gray_impl=gray_impl, **kw)


def _tiny_generic_engine():
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core.generic import GenericFlashEngine
    from repro.models.gla import GLALM

    cfg = dataclasses.replace(
        get_config("gla").smoke(), name="gla-staticcheck",
        n_layers=2, d_model=16, d_ff=32, vocab=64, gla_dk=4, gla_dv=8)
    model = GLALM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return GenericFlashEngine(model, params, batch=4, gen_max=16,
                              prompt_max=4)


def _entry_args(eng):
    """(state, pv, origin, live, rng, prompt) argument pack for tracing."""
    import jax
    import jax.numpy as jnp

    state = eng.init_state()
    pv = jnp.zeros((eng.batch,), jnp.int32)
    live = jnp.ones((eng.batch,), bool)
    rng = jax.random.PRNGKey(0)
    # prefill takes the EMBEDDED prompt (1, P, D) — mirror the serving
    # backends' admission path (model.embed_tokens where the model has a
    # token embedding; the synthetic LCSM feeds activations directly).
    if hasattr(eng.model, "embed_tokens"):
        prompt = eng.model.embed_tokens(eng.params,
                                        jnp.zeros((1, 4), jnp.int32))
    else:
        prompt = jnp.zeros((1, 4, eng.model.d), jnp.float32)
    return state, pv, live, rng, prompt


def _run_engine_entries(eng, prefix: str, mesh_name: str | None,
                        include_decode: bool) -> list[dict]:
    import jax
    import jax.numpy as jnp

    out = []
    state, pv, live, rng, prompt = _entry_args(eng)
    n_leaves = len(jax.tree.leaves(state))

    if include_decode:
        # Populate the segment-keyed cache through the public surface, then
        # verify the cached program — proves the REGISTERED donate spec.
        st = eng.init_state()
        donated_ref = jax.tree.leaves(st)
        eng.decode_chunk(st, 0, rng, _SIDES)
        fn = eng._jit_chunk[_SIDES]
        # Runtime proof on top of the lowering attrs: the concrete call
        # above must actually have freed the donated input buffers.
        extra = [_check("donated_buffer_deleted", True,
                        all(leaf.is_deleted() for leaf in donated_ref))]
        out.append(_verdict(
            f"{prefix}.decode_chunk", fn, (eng.params, state, pv, rng),
            n_donated=n_leaves, splits=len(_SIDES), mesh=mesh_name,
            extra_checks=extra))

    eng.server_chunk(eng.init_state(), pv, pv, live, rng, K_STEPS,
                     dispatch="batched")
    fn = eng._jit_server_chunk[(K_STEPS, "batched")]
    extra = []
    if prefix == "FlashEngine":
        # Positive control: the retired ladder must still SHOW conds, or
        # the cond counter proves nothing.
        ref = jax.jit(functools.partial(eng._server_chunk_impl, K=K_STEPS,
                                        dispatch="reference"))
        ref_jaxpr = jax.make_jaxpr(ref)(
            eng.params, state, pv, pv, live, rng)
        n_cond = _count_primitives(ref_jaxpr, {"cond"})["cond"]
        extra.append(_check("reference_ladder_has_conds", True, n_cond > 0))
    out.append(_verdict(
        f"{prefix}.server_chunk[batched]", fn,
        (eng.params, state, pv, pv, live, rng),
        n_donated=n_leaves, splits=K_STEPS, mesh=mesh_name,
        extra_checks=extra))

    plen = jnp.asarray(4, jnp.int32)
    slot = jnp.asarray(0, jnp.int32)
    out.append(_verdict(
        f"{prefix}.prefill_slot", eng._jit_prefill_slot,
        (eng.params, state, slot, prompt, plen, rng),
        n_donated=n_leaves, splits=0, mesh=mesh_name))
    return out


def _trace_invariance_verdict() -> dict:
    """The flashtrace hard contract checked where it binds: the jaxpr of a
    hot chunk program must be CHARACTER-IDENTICAL whether tracing is
    enabled or not — obs must never reach the traced side, so enabling it
    cannot change (or even re-order) a single equation.  Two fresh tiny
    engines are traced (no shared jit cache), one with the recorder off,
    one with it on."""
    import hashlib

    import jax

    from repro.obs import trace as obs

    def chunk_jaxpr() -> str:
        eng = _tiny_flash_engine()
        state, pv, live, rng, _ = _entry_args(eng)
        fn = functools.partial(eng._server_chunk_impl, K=K_STEPS,
                               dispatch="batched")
        return str(jax.make_jaxpr(fn)(eng.params, state, pv, pv, live, rng))

    def sha(s: str) -> str:
        return hashlib.sha1(s.encode()).hexdigest()[:16]

    off = chunk_jaxpr()
    prev = obs.RECORDER
    obs.enable_tracing()
    try:
        on = chunk_jaxpr()
    finally:
        obs.RECORDER = prev
    checks = [_check("jaxpr_identical_with_tracing", sha(off), sha(on))]
    return {"entry": "flashtrace.trace_invariance",
            "devices": jax.device_count(), "mesh": None,
            "checks": checks, "ok": all(c["ok"] for c in checks)}


def run_jaxpr_pass() -> list[dict]:
    """Trace every registered entry point under the current device config.
    Returns one verdict dict per (entry, mesh config)."""
    import jax

    out: list[dict] = []
    out += _run_engine_entries(_tiny_flash_engine(), "FlashEngine",
                               None, include_decode=True)
    # The fused-kernel dispatch (gray_impl="pallas") swaps the gray/red hot
    # path for pallas_calls with aliased b buffers — donation, cond-freedom
    # and the rng schedule must survive the swap, so its chunk programs are
    # first-class registered entries, not a variant left to unit tests.
    out += _run_engine_entries(_tiny_flash_engine(gray_impl="pallas"),
                               "FlashEngine[gray_impl=pallas]",
                               None, include_decode=True)
    out += _run_engine_entries(_tiny_generic_engine(), "GenericFlashEngine",
                               None, include_decode=False)
    out.append(_trace_invariance_verdict())
    if jax.device_count() >= 4:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(data=4)
        out += _run_engine_entries(_tiny_flash_engine(mesh=mesh),
                                   "FlashEngine", "data4",
                                   include_decode=True)
    return out
