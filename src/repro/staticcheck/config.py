"""staticcheck.toml — baseline/suppression file for flashcheck.

Suppressions are DOCUMENTED exceptions, matched by (rule, path, symbol)
rather than line numbers so they survive unrelated edits:

    [[suppress]]
    rule   = "FC003"
    path   = "src/repro/models/gla.py"
    symbol = "logits"          # enclosing function; "*" = whole file
    reason = "why this site is exempt (required)"

``[analyzer]`` holds run options:

    [analyzer]
    exclude = ["tests/fixtures/staticcheck"]   # path prefixes to skip

Every suppression must carry a non-empty ``reason`` — an empty reason is
itself a config error (the point of the file is the justification).
"""

from __future__ import annotations

import fnmatch
from dataclasses import dataclass, field
from pathlib import Path

try:  # Python 3.11+
    import tomllib as _toml
except ImportError:  # pragma: no cover — 3.10 container
    import tomli as _toml  # type: ignore[no-redef]


@dataclass(frozen=True)
class Suppression:
    rule: str
    path: str      # repo-relative posix path or glob
    symbol: str    # enclosing function name, "*" matches any
    reason: str

    def matches(self, rule: str, path: str, symbol: str) -> bool:
        if self.rule != rule:
            return False
        if not (path == self.path or fnmatch.fnmatch(path, self.path)):
            return False
        return self.symbol == "*" or self.symbol == symbol


@dataclass
class Config:
    suppressions: list[Suppression] = field(default_factory=list)
    exclude: list[str] = field(default_factory=list)

    def suppression_for(self, rule: str, path: str, symbol: str) -> str:
        """Reason string of the first matching suppression, else ''."""
        for s in self.suppressions:
            if s.matches(rule, path, symbol):
                return s.reason
        return ""

    def is_excluded(self, rel_path: str) -> bool:
        return any(rel_path == e or rel_path.startswith(e.rstrip("/") + "/")
                   or fnmatch.fnmatch(rel_path, e) for e in self.exclude)


def load_config(path: str | Path | None) -> Config:
    """Load staticcheck.toml (missing file = empty config)."""
    if path is None:
        return Config()
    p = Path(path)
    if not p.exists():
        return Config()
    with open(p, "rb") as fh:
        raw = _toml.load(fh)
    sups = []
    for ent in raw.get("suppress", []):
        reason = ent.get("reason", "").strip()
        if not reason:
            raise ValueError(
                f"staticcheck.toml suppression for {ent.get('rule')} at "
                f"{ent.get('path')} has no reason — document the exception")
        sups.append(Suppression(
            rule=ent["rule"], path=ent["path"],
            symbol=ent.get("symbol", "*"), reason=reason))
    analyzer = raw.get("analyzer", {})
    return Config(suppressions=sups,
                  exclude=list(analyzer.get("exclude", [])))
