"""FC001–FC007: the AST-level contracts flashcheck enforces.

Each rule encodes an invariant a shipped PR learned the hard way
(CHANGES.md is the provenance trail):

  FC001  use-after-donate              (PR 2: bench_tokentime donation)
  FC002  mixed-dtype dynamic_slice starts (PR 3: x64 int32/int64 mixes)
  FC003  dot/einsum/@ in mul+sum-pinned mixer modules (PR 4: GLA bit-identity)
  FC004  lax.cond reachable from hot dispatch (PR 6: cond-ladder retirement)
  FC005  unbounded dict-keyed jit caches (PR 5: prompt-length retrace blowup)
  FC006  global config toggles at test import scope (PR 3: x64 leak)
  FC007  host callbacks / repro.obs reachable from traced bodies
         (PR 10: flashtrace must never enter a jitted program)

Rules favor a LOW false-positive bias: an unresolvable expression is
skipped, not flagged — the fixture corpus in tests/fixtures/staticcheck
pins exactly what each rule must and must not catch.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass

from .astutil import (
    CallGraph,
    FuncInfo,
    TaintLite,
    assigned_names,
    callee_names,
    dotted_name,
    enclosing_loops,
    enclosing_stmt,
    index_functions,
    last_segment,
    loads_of,
)
from .config import Config
from .findings import ERROR, WARN, Finding

# --- FC001: engine/walker methods that donate their call-arg-0 state.
# Matched on ATTRIBUTE calls only (eng.decode_chunk(...)) — the launch/
# lcsm_steps pure functions reuse some of these names without donating.
DONATING_METHODS = {
    "decode_chunk", "server_chunk", "prefill_slot", "tiles_step",
    "red_step", "lazy_step", "eager_step", "gray_step", "import_slot_rows",
}
# _schedule_step(params, state, pv, rng, ...) threads state into the
# donated per-piece jits — its state arg is consumed just the same.
DONATING_METHOD_ARGS = {name: (0,) for name in DONATING_METHODS}
DONATING_METHOD_ARGS["_schedule_step"] = (1,)

# --- FC002: lax slicing family -> positional index of the starts tuple.
SLICE_STARTS_ARG = {"dynamic_slice": 1, "dynamic_update_slice": 2}

# --- FC003: modules whose contractions are pinned to mul+sum.
MIXER_PINNED = ("src/repro/models/gla.py", "src/repro/core/generic.py")
CONTRACTION_CALLS = {"einsum", "dot", "dot_general", "matmul",
                     "tensordot", "vdot"}

# --- FC004 roots / whitelist.
FC004_ROOTS = ["server_chunk", "decode_chunk",
               "_server_chunk_impl", "_decode_chunk_impl"]
FC004_WHITELIST = {"_server_tiles_reference"}

# --- FC007 roots: the TRACED bodies — functions that become jitted
# programs.  The host-side wrappers (decode_chunk, server_chunk, prefill,
# ...) legitimately call repro.obs around the dispatch; the ban is on the
# traced side of the boundary only, where a flashtrace call would either
# fail to trace or (worse) bake a host callback into the program and
# break the tracing-on == tracing-off bitwise contract.
FC007_ROOTS = [
    "_decode_chunk_impl", "_server_chunk_impl", "_schedule_step",
    "_server_tiles", "_server_tiles_batched", "_server_tiles_reference",
    "_red_pass", "_gray_tile", "_lazy_fill", "_eager_push",
    "_prefill_rows", "_prefill_slot_impl", "_import_slot_rows_impl",
]
# Call names that smuggle host execution into a traced program.  "callback"
# alone is too generic a last segment — jax.debug.callback / debug.print
# are matched on their dotted form instead.
HOST_CALLBACK_CALLS = {"io_callback", "pure_callback", "host_callback",
                       "debug_callback"}
OBS_PATH_PREFIX = "src/repro/obs/"
# Reach is cut at the host-wrapper names: the name-based graph merges
# same-named functions (the traced GLA nested `step` vs the host backend
# `step`), which would otherwise carry reach back across the dispatch
# boundary and into the wrappers' LEGITIMATE obs calls.  Every name here
# is a host-side surface; none is a traced body.
FC007_BLOCKED = DONATING_METHODS | {
    "prefill", "prefill_slot", "step", "step_chunk", "dispatch_chunk",
    "collect_chunk", "generate", "run", "serve", "submit",
}

# --- FC005: cache-dict naming + key normalizers that prove boundedness.
CACHE_NAME_RE = re.compile(r"^_jit|cache", re.IGNORECASE)
BOUNDED_KEY_CALLS = {"tuple", "int", "bool", "str", "min", "max", "len",
                     "frozenset", "ceil_pow2", "largest_pow2_divisor",
                     "schedule_segment"}


@dataclass
class Module:
    path: str          # repo-relative posix path
    tree: ast.Module


def own_nodes(root: ast.AST):
    """Descendants of ``root`` without entering nested def/class scopes
    (lambdas and comprehensions stay — they share the enclosing frame)."""
    stack = list(ast.iter_child_nodes(root))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def _scopes(mod: Module) -> list[FuncInfo]:
    """Every function plus a pseudo-scope for module-level statements."""
    return index_functions(mod.tree, mod.path) + [
        FuncInfo("", "<module>", mod.tree, mod.path)]


def _own_assigns(scope: ast.AST) -> dict[str, ast.expr]:
    """name -> last assigned value expr within the scope (one-hop lookup)."""
    out: dict[str, ast.expr] = {}
    for node in own_nodes(scope):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            out[node.targets[0].id] = node.value
    return out


def _jit_table(mod: Module) -> dict[str, tuple[int, ...]]:
    """last-segment name -> donated arg indices, inferred from
    ``X = jax.jit(fn, donate_argnums=(...))`` assignments (literal tuples
    or single int literals only; dynamic donate specs are skipped)."""
    table: dict[str, tuple[int, ...]] = {}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign):
            continue
        call = node.value
        if not (isinstance(call, ast.Call)
                and last_segment(dotted_name(call.func)) in ("jit", "pjit")):
            continue
        idxs: tuple[int, ...] = ()
        for kw in call.keywords:
            if kw.arg != "donate_argnums":
                continue
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                idxs = (v.value,)
            elif isinstance(v, (ast.Tuple, ast.List)) and all(
                    isinstance(e, ast.Constant) and isinstance(e.value, int)
                    for e in v.elts):
                idxs = tuple(e.value for e in v.elts)
        if not idxs:
            continue
        for tgt in node.targets:
            seg = last_segment(dotted_name(tgt))
            if seg:
                table[seg] = idxs
    return table


class Checker:
    """Runs the per-file rules over one module and FC004 over the set."""

    def __init__(self, config: Config):
        self.config = config
        self.findings: list[Finding] = []

    def emit(self, rule: str, mod_path: str, node: ast.AST, symbol: str,
             message: str, hint: str, severity: str = ERROR) -> None:
        reason = self.config.suppression_for(rule, mod_path, symbol or "*")
        self.findings.append(Finding(
            rule=rule, path=mod_path, line=getattr(node, "lineno", 1),
            message=message, hint=hint, symbol=symbol, severity=severity,
            suppressed_by=reason))

    # ------------------------------------------------------------ FC001
    def fc001(self, mod: Module) -> None:
        jit_table = _jit_table(mod)
        for fi in _scopes(mod):
            for call in own_nodes(fi.node):
                if not isinstance(call, ast.Call):
                    continue
                donated: set[int] = set()
                callee = ""
                for cand in callee_names(call):
                    seg = last_segment(cand) or ""
                    if seg in jit_table:
                        donated.update(jit_table[seg])
                        callee = callee or cand
                    if "." in cand and seg in DONATING_METHOD_ARGS:
                        donated.update(DONATING_METHOD_ARGS[seg])
                        callee = callee or cand
                if not donated:
                    continue
                stmt = enclosing_stmt(fi.node, call)
                if stmt is None:
                    continue
                for idx in sorted(donated):
                    if idx >= len(call.args):
                        continue
                    name = dotted_name(call.args[idx])
                    if name is None or name == "self":
                        continue
                    self._check_donated_use(mod, fi, call, stmt, callee, name)

    def _check_donated_use(self, mod: Module, fi: FuncInfo, call: ast.Call,
                           stmt: ast.stmt, callee: str, name: str) -> None:
        rebound = any(t == name or name.startswith(t + ".")
                      for t in assigned_names(stmt))
        if rebound:
            return
        call_end = stmt.end_lineno or stmt.lineno
        binds = []
        for node in ast.walk(fi.node):
            if isinstance(node, ast.stmt) and node is not stmt:
                if any(t == name or name.startswith(t + ".")
                       for t in assigned_names(node)):
                    binds.append(node.end_lineno or node.lineno)
        loads = [n for n in loads_of(fi.node, name)
                 if not (stmt.lineno <= n.lineno <= call_end)]
        first_rebind = min((b for b in binds if b > call_end), default=None)
        dangerous = [n for n in loads if n.lineno > call_end
                     and (first_rebind is None or n.lineno < first_rebind)]
        # Inside a loop the donation wraps around: a read ABOVE the call is
        # next iteration's read of the deleted buffer unless some bind
        # intervenes (after the call, or between loop top and the read).
        for loop in enclosing_loops(fi.node, stmt):
            lo, hi = loop.lineno, loop.end_lineno or loop.lineno
            loop_binds = [b for b in binds if lo <= b <= hi]
            for n in loads:
                if lo <= n.lineno <= call_end and not any(
                        b > call_end or b < n.lineno for b in loop_binds):
                    dangerous.append(n)
        if not dangerous:
            return
        worst = min(dangerous, key=lambda n: (n.lineno, n.col_offset))
        self.emit(
            "FC001", mod.path, worst, fi.name,
            f"'{name}' is read after being donated to {callee}() — "
            f"XLA deletes donated buffers, so this read sees freed memory",
            f"rebind from the call result: `{name}, ... = {callee}(...)` "
            f"(donation threads state linearly; CHANGES.md PR 2)")

    # ------------------------------------------------------------ FC002
    def fc002(self, mod: Module) -> None:
        for fi in _scopes(mod):
            taint = TaintLite(fi.node)
            assigns = _own_assigns(fi.node)
            for call in own_nodes(fi.node):
                if not isinstance(call, ast.Call):
                    continue
                seg = last_segment(dotted_name(call.func))
                if seg not in SLICE_STARTS_ARG:
                    continue
                pos = SLICE_STARTS_ARG[seg]
                starts = None
                if len(call.args) > pos:
                    starts = call.args[pos]
                else:
                    for kw in call.keywords:
                        if kw.arg == "start_indices":
                            starts = kw.value
                elems = _flatten_starts(starts, assigns)
                if not elems or len(elems) < 2:
                    continue
                lits = [e for e in elems if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)]
                traced = [e for e in elems if taint.expr_suspect(e)]
                if traced and (lits or len(traced) < len(elems)):
                    self.emit(
                        "FC002", mod.path, call, fi.name,
                        f"{seg} start tuple mixes Python-int and traced-int "
                        f"elements — JAX_ENABLE_X64 promotes the host ints "
                        f"to int64 and lax rejects the int32/int64 mix",
                        "route the tuple through a starts() helper that "
                        "casts every element to the traced index dtype "
                        "(core/schedule.py:starts, launch/lcsm_steps.py:"
                        "_starts; CHANGES.md PR 3)")

    # ------------------------------------------------------------ FC003
    def fc003(self, mod: Module) -> None:
        if mod.path not in MIXER_PINNED:
            return
        for fi in _scopes(mod):
            for node in own_nodes(fi.node):
                what = None
                if (isinstance(node, ast.Call)
                        and last_segment(dotted_name(node.func))
                        in CONTRACTION_CALLS):
                    what = last_segment(dotted_name(node.func))
                elif isinstance(node, ast.BinOp) and isinstance(
                        node.op, ast.MatMult):
                    what = "@"
                if what is None:
                    continue
                self.emit(
                    "FC003", mod.path, node, fi.name,
                    f"{what} contraction in a mul+sum-pinned mixer module — "
                    f"XLA lowers small dots differently per fusion context, "
                    f"breaking chunked-vs-stepwise bit-identity",
                    "rewrite as an elementwise product + sum over the "
                    "contracted axis: (a * b).sum(-1) (CHANGES.md PR 4)")

    # ------------------------------------------------------------ FC004
    def fc004(self, modules: list[Module]) -> None:
        graph = CallGraph.build([(m.path, m.tree) for m in modules])
        reach = graph.reach(FC004_ROOTS, FC004_WHITELIST)
        seen: set[tuple[str, int]] = set()
        for name in sorted(reach):
            for fi in graph.funcs.get(name, []):
                for node in ast.walk(fi.node):
                    if not _is_lax_cond(node):
                        continue
                    key = (fi.path, node.lineno)
                    if key in seen:
                        continue
                    seen.add(key)
                    chain = " -> ".join(reach[name])
                    self.emit(
                        "FC004", fi.path, node, fi.name,
                        f"lax.cond reachable from hot dispatch ({chain}) — "
                        f"data-dependent branching serializes the GSPMD "
                        f"schedule and reintroduces the per-side ladder",
                        "mask-select with jnp.where / batched gather-scatter "
                        "(_server_tiles_batched); only the whitelisted "
                        "_server_tiles_reference keeps a cond ladder "
                        "(CHANGES.md PR 6)")

    # ------------------------------------------------------------ FC007
    def fc007(self, modules: list[Module]) -> None:
        """No host callbacks and no repro.obs code reachable from the
        traced hot bodies (module doc).  Same over-approximating name
        graph as FC004: the right bias for a reachability ban."""
        graph = CallGraph.build([(m.path, m.tree) for m in modules])
        reach = graph.reach(FC007_ROOTS, FC007_BLOCKED)
        seen: set[tuple[str, int]] = set()

        def hit(path: str, node: ast.AST, symbol: str, message: str) -> None:
            key = (path, getattr(node, "lineno", 1))
            if key in seen:
                return
            seen.add(key)
            self.emit(
                "FC007", path, node, symbol, message,
                "move the instrumentation to the host wrapper around the "
                "dispatch (rec = _obs.RECORDER; if rec is None: ... "
                "pattern) — flashtrace must never enter a jitted program "
                "(README Observability; CHANGES.md PR 10)")

        for name in sorted(reach):
            chain = " -> ".join(reach[name])
            for fi in graph.funcs.get(name, []):
                if fi.path.startswith(OBS_PATH_PREFIX):
                    hit(fi.path, fi.node, fi.name,
                        f"repro.obs function '{fi.name}' is reachable from "
                        f"a traced hot body ({chain}) — tracing must stay "
                        f"on the host side of the dispatch boundary")
                    continue
                for node in ast.walk(fi.node):
                    bad = _host_callback_name(node)
                    if bad is not None:
                        hit(fi.path, node, fi.name,
                            f"host callback {bad}() reachable from a traced "
                            f"hot body ({chain}) — it bakes host execution "
                            f"into the jitted program, so tracing on/off "
                            f"changes the compiled computation")
                    elif (isinstance(node, (ast.Import, ast.ImportFrom))
                          and _imports_obs(node)):
                        hit(fi.path, node, fi.name,
                            f"repro.obs imported inside a traced hot body "
                            f"({chain}) — instrumentation belongs in the "
                            f"host wrapper, not the traced function")

    # ------------------------------------------------------------ FC005
    def fc005(self, mod: Module) -> None:
        for fi in _scopes(mod):
            assigns = _own_assigns(fi.node)
            for node in own_nodes(fi.node):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Subscript)):
                    continue
                base = last_segment(dotted_name(node.targets[0].value))
                if base is None or not CACHE_NAME_RE.search(base):
                    continue
                if _key_bounded(node.targets[0].slice, assigns):
                    continue
                self.emit(
                    "FC005", mod.path, node, fi.name,
                    f"cache dict '{base}' written under a key not proven "
                    f"bounded — per-key jit programs accumulate for the "
                    f"process lifetime",
                    "normalize the key to a bounded domain (pow2 bucket via "
                    "ceil_pow2, canonical schedule_segment tuple) or add a "
                    "documented staticcheck.toml suppression "
                    "(CHANGES.md PR 5)", severity=WARN)
        # The memoization-decorator arm only polices production code: an
        # unbounded lru_cache on a 0-arg test fixture is trivially bounded.
        if not mod.path.startswith("src/"):
            return
        for fi in index_functions(mod.tree, mod.path):
            args = getattr(fi.node, "args", None)
            if args is None or not (args.posonlyargs + args.args
                                    + args.kwonlyargs):
                continue
            for dec in getattr(fi.node, "decorator_list", []):
                if _is_unbounded_lru(dec):
                    self.emit(
                        "FC005", mod.path, dec, fi.name,
                        "functools cache with maxsize=None memoizes an "
                        "unbounded key domain",
                        "bound the domain (or suppress with a reason "
                        "documenting why the key set is finite)",
                        severity=WARN)

    # ------------------------------------------------------------ FC006
    def fc006(self, mod: Module) -> None:
        if not mod.path.startswith("tests/"):
            return
        for node in own_nodes(mod.tree):
            bad = None
            if isinstance(node, ast.Call):
                dn = dotted_name(node.func) or ""
                if dn.endswith("config.update"):
                    bad = f"{dn}(...)"
                elif (dn.endswith("environ.setdefault")
                      and _env_key_is_jax(node.args[:1])):
                    bad = f"{dn}(...)"
            elif (isinstance(node, ast.Assign)
                  and len(node.targets) == 1
                  and isinstance(node.targets[0], ast.Subscript)
                  and (dotted_name(node.targets[0].value) or ""
                       ).endswith("environ")
                  and _env_key_is_jax([node.targets[0].slice])):
                bad = "os.environ[...] write"
            if bad is None:
                continue
            self.emit(
                "FC006", mod.path, node, "",
                f"{bad} at module import scope in tests/ — the toggle leaks "
                f"into every other collected test module (x64 flips flushed "
                f"a whole-suite dtype break in PR 3)",
                "scope it in a fixture with teardown, or run the variant in "
                "a subprocess (tests/test_core_tiling.py pattern)")


def _flatten_starts(expr, assigns: dict[str, ast.expr],
                    depth: int = 0) -> list[ast.expr] | None:
    """Element list of a starts tuple, through one Name hop and through
    the ``(a, b) + (0,) * k`` concat/repeat idioms.  None = unresolvable
    (skip — low-FP bias)."""
    if expr is None or depth > 4:
        return None
    if isinstance(expr, (ast.Tuple, ast.List)):
        return list(expr.elts)
    if isinstance(expr, ast.Name) and expr.id in assigns:
        return _flatten_starts(assigns[expr.id], assigns, depth + 1)
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Add):
        left = _flatten_starts(expr.left, assigns, depth + 1)
        right = _flatten_starts(expr.right, assigns, depth + 1)
        if left is None or right is None:
            return None
        return left + right
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Mult):
        return _flatten_starts(expr.left, assigns, depth + 1)
    return None


def _key_bounded(expr, assigns: dict[str, ast.expr], depth: int = 0) -> bool:
    if depth > 3:
        return False
    if isinstance(expr, ast.Constant):
        return True
    if isinstance(expr, (ast.Tuple, ast.List)):
        return all(_key_bounded(e, assigns, depth + 1) for e in expr.elts)
    if isinstance(expr, ast.Call):
        return last_segment(dotted_name(expr.func)) in BOUNDED_KEY_CALLS
    if isinstance(expr, ast.Name) and expr.id in assigns:
        return _key_bounded(assigns[expr.id], assigns, depth + 1)
    return False


def _host_callback_name(node: ast.AST) -> str | None:
    if not isinstance(node, ast.Call):
        return None
    for cand in callee_names(node):
        seg = last_segment(cand)
        if seg in HOST_CALLBACK_CALLS:
            return cand
        if cand.endswith("debug.callback") or cand.endswith("debug.print"):
            return cand
    return None


def _imports_obs(node: ast.AST) -> bool:
    if isinstance(node, ast.Import):
        return any(a.name.startswith("repro.obs") for a in node.names)
    if isinstance(node, ast.ImportFrom):
        mod = node.module or ""
        return mod.startswith("repro.obs") or (
            mod == "repro" and any(a.name == "obs" for a in node.names))
    return False


def _is_lax_cond(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    return (isinstance(f, ast.Attribute) and f.attr == "cond"
            and dotted_name(f.value) in ("lax", "jax.lax"))


def _is_unbounded_lru(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Call):
        seg = last_segment(dotted_name(dec.func))
        if seg == "lru_cache":
            for kw in dec.keywords:
                if (kw.arg == "maxsize" and isinstance(kw.value, ast.Constant)
                        and kw.value.value is None):
                    return True
            return False
    return last_segment(dotted_name(dec)) == "cache" and isinstance(
        dec, ast.Attribute) and "functools" in (dotted_name(dec) or "")


def _env_key_is_jax(exprs) -> bool:
    for e in exprs:
        if isinstance(e, ast.Constant) and isinstance(e.value, str):
            return e.value.startswith(("JAX_", "XLA_"))
    return False


def run_rules(modules: list[Module], config: Config) -> list[Finding]:
    chk = Checker(config)
    for mod in modules:
        chk.fc001(mod)
        chk.fc002(mod)
        chk.fc003(mod)
        chk.fc005(mod)
        chk.fc006(mod)
    chk.fc004(modules)
    chk.fc007(modules)
    chk.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return chk.findings
