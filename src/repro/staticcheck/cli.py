"""flashcheck CLI — ``python -m repro.staticcheck [paths...]``.

    python -m repro.staticcheck src tests benchmarks         # AST rules
    python -m repro.staticcheck --fail-on-warn --jaxpr ...   # CI lint leg
    python -m repro.staticcheck --jaxpr-only                 # variants leg
    python -m repro.staticcheck --json report.json ...       # BENCH artifact

Exit code 0 = clean (modulo staticcheck.toml suppressions), 1 = findings
(or any jaxpr contract failure), 2 = usage/config error.
"""

from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path

from .config import load_config
from .findings import ERROR, Finding, Report
from .rules import Module, run_rules

DEFAULT_PATHS = ("src", "tests", "benchmarks")


def discover(paths, config) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_file() and path.suffix == ".py":
            files.append(path)
        elif path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
    out = []
    for f in files:
        rel = f.as_posix()
        if not config.is_excluded(rel):
            out.append(f)
    return out


def analyze(paths, config, *, jaxpr: bool, ast_rules: bool = True) -> Report:
    report = Report()
    if ast_rules:
        modules: list[Module] = []
        for f in discover(paths, config):
            rel = f.as_posix()
            try:
                tree = ast.parse(f.read_text(), filename=rel)
            except SyntaxError as e:
                report.findings.append(Finding(
                    rule="PARSE", path=rel, line=e.lineno or 1,
                    message=f"syntax error: {e.msg}", severity=ERROR))
                continue
            modules.append(Module(path=rel, tree=tree))
        report.files_scanned = len(modules)
        report.findings.extend(run_rules(modules, config))
    if jaxpr:
        from .jaxpr_pass import run_jaxpr_pass
        report.jaxpr = run_jaxpr_pass()
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description="flashcheck: AST+jaxpr contract analyzer for the "
                    "Flash-Inference serving invariants (FC001-FC007)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to scan (default: {DEFAULT_PATHS})")
    ap.add_argument("--baseline", default="staticcheck.toml",
                    help="suppression file (default: ./staticcheck.toml)")
    ap.add_argument("--fail-on-warn", action="store_true",
                    help="exit 1 on WARN findings too (CI mode)")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH", help="write the JSON report to PATH "
                    "('-' or no value = stdout)")
    ap.add_argument("--jaxpr", action="store_true",
                    help="also trace the registered hot entry points and "
                    "verify donation / cond-free / rng-split contracts")
    ap.add_argument("--jaxpr-only", action="store_true",
                    help="run only the jaxpr pass (forced-device CI legs)")
    args = ap.parse_args(argv)

    try:
        config = load_config(args.baseline)
    except (ValueError, KeyError) as e:
        print(f"staticcheck: config error: {e}", file=sys.stderr)
        return 2

    paths = args.paths or list(DEFAULT_PATHS)
    report = analyze(paths, config,
                     jaxpr=args.jaxpr or args.jaxpr_only,
                     ast_rules=not args.jaxpr_only)

    for f in report.findings:
        print(f.render())
    for entry in report.jaxpr:
        status = "ok" if entry["ok"] else "FAIL"
        mesh = f" mesh={entry['mesh']}" if entry["mesh"] else ""
        print(f"jaxpr {status}: {entry['entry']} "
              f"[{entry['devices']} device(s){mesh}]")
        for c in entry["checks"]:
            if not c["ok"]:
                print(f"    {c['name']}: expected {c['expected']!r}, "
                      f"got {c['actual']!r}")

    counts = report.counts()
    print(f"flashcheck: {report.files_scanned} files, "
          f"{counts['findings']} finding(s), "
          f"{counts['suppressed']} suppressed, "
          f"{counts['jaxpr_entry_points']} jaxpr entry point(s), "
          f"{counts['jaxpr_failures']} jaxpr failure(s)")

    if args.json is not None:
        payload = json.dumps(report.to_dict(), indent=2, sort_keys=True)
        if args.json == "-":
            print(payload)
        else:
            Path(args.json).write_text(payload + "\n")
            print(f"flashcheck: JSON report -> {args.json}")

    return 1 if report.failed(args.fail_on_warn) else 0
