"""Fused Pallas kernels for the Algorithm-2 tile hot path.

The XLA gray-tile body (``FlashEngine._gray_tile``) is a three-op chain
per conv-width group: per-slot dynamic-slice *gather* of the U input
rows, a τ tile conv, and a masked horizon-clipped ``.at[].add`` *scatter*
into the ``b`` accumulators — three HBM round-trips over (B, Lbuf, C)
planes for O(B·U·C) useful work.  ``gray_tile_apply`` fuses the chain
into ONE kernel: each grid program holds a slot-block's a/b planes in
VMEM, gathers the y window with an in-kernel dynamic row slice, runs the
direct τ block, and accumulates into the b window in place
(``input_output_aliases`` pins b input g to output g, so XLA can donate
the accumulator buffers straight through).  ``red_pass_fma`` fuses the
per-step red-cell gather + FMA (``b[p] + y[p]·rho_0``) the same way.

Bitwise contract (pinned by tests/test_kernels.py + test_decode_chunk.py)
-------------------------------------------------------------------------
Both kernels are bitwise-identical in interpret mode to the XLA
reference bodies they replace.  Two empirically-load-bearing details:

* τ block form: jitted ``tau_direct`` (take + einsum with
  ``preferred_element_type=f32``) is reproduced bitwise inside the
  kernel by the same take+einsum for U == 1 and U >= 4, but at U == 2
  XLA emits the tiny contraction as a REVERSE-order multiply-add chain —
  so the kernel dispatches on U (measured over U ∈ {1..256} ×
  C ∈ {3..200}; forward-order FMA is never bitwise for U >= 2).
* accumulate form: XLA's CPU fusion emitter contracts adjacent mul+add
  into one FMA — and neither ``optimization_barrier`` nor an
  intervening ``select`` stops it (measured).  The reference gray body
  is immune because its accumulate is a *scatter*, so the interpret
  path mirrors its ``add_tile`` op-for-op (scatter-adds +0.0 into the
  horizon-clamped row Lbuf-1 for spilled outputs, flipping a stored
  -0.0 to +0.0; untouched rows are never written).  The Mosaic path —
  where no contraction pass exists and scatter has no lowering — uses
  the equivalent clamped-window + select form with an explicit
  ``contrib + 0.0`` on the duplicated last row.  The red-cell FMA is
  the mirror case: the reference's own mul+add DOES contract, so the
  red kernel keeps the bare mul+add pattern.  One residual hole: at
  U == 1 the lcsm τ degenerates to a bare multiply and XLA contracts
  it into the accumulate *fusion-context-dependently* (some levels of
  some groups, not others), so no fixed op shape can pin it —
  ``heuristic.gray_plan(min_u=2)`` keeps U=1 lcsm tiles on the XLA
  body.  Select mode is safe at U=1: the reference ``_apply_tile``
  has the same take_along_axis between τ and add as the kernel, and
  the gather blocks contraction symmetrically.

Two accumulate modes mirror the two engines:

* ``mode="lcsm"`` — ``FlashEngine._gray_tile``: mask pre-zeroes the τ
  output, the scatter-add still touches valid/clamped rows of masked-out
  slots (with zeros), horizon spill clips by zero-add at row Lbuf-1.
* ``mode="select"`` — ``generic._apply_tile``: no absorbing zero; rows
  outside ``(rel >= 0) & mask`` keep their old value exactly (a select,
  not an add), so an all-False-mask call is a fully bitwise no-op.

Layout: grid = (B / slot_block,); each program sees whole (Lbuf, W)
planes for its slots (channels on lanes, rows on sublanes) plus one
shared (G, 2U, C) filter block mapped to block (0, 0, 0) for every
program — the multi-level analogue of tile_conv's shared-filter
BlockSpec.  Positions/masks ride in as scalar-prefetch operands
(SMEM), so the row windows are known before the DMA pipeline runs.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_F32 = jnp.float32


def _tau_block(y: jnp.ndarray, rho: jnp.ndarray, U: int) -> jnp.ndarray:
    """Direct τ on one (U, C) f32 tile — bitwise vs jitted ``tau_direct``.

    U == 2 needs the reverse-order FMA chain; every other U needs the
    take+einsum form (see module docstring).  Both are O(U^2 C).
    """
    if U == 2:
        acc = y[1, :][None, :] * jax.lax.slice_in_dim(rho, 1, 3, axis=0)
        return acc + y[0, :][None, :] * jax.lax.slice_in_dim(rho, 2, 4, axis=0)
    t = jnp.arange(U)
    band = U + t[:, None] - t[None, :]          # (U, U) lags in [1, 2U-1]
    rmat = jnp.take(rho, band, axis=0)          # (U, U, C)
    return jnp.einsum("tsc,sc->tc", rmat, y, preferred_element_type=_F32)


def _gray_kernel(p_ref, m_ref, *refs, G: int, U: int, Lbuf: int, C: int,
                 conv_starts: Sequence[int], slot_block: int, mode: str,
                 a_dtype, interpret: bool):
    """One slot-block: all G levels of one conv-width group, fused.

    refs = (a_0..a_{G-1}, b_0..b_{G-1}, rho, out_0..out_{G-1});
    out_g aliases b_g.  p_ref/m_ref are full-(B,) scalar-prefetch refs.
    """
    a_refs = refs[:G]
    b_refs = refs[G:2 * G]
    rho_ref = refs[2 * G]
    out_refs = refs[2 * G + 1:]
    i = pl.program_id(0)
    # Seed every output block with its aliased accumulator so untouched
    # rows round-trip bitwise (on hardware the whole block writes back).
    for g in range(G):
        out_refs[g][...] = b_refs[g][...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (U, C), 0)
    for j in range(slot_block):
        slot = i * slot_block + j
        pj = p_ref[slot]
        mj = m_ref[slot] != 0
        # Gather window [p-U+1, p] and scatter window [p+1, p+U], both
        # clamped exactly like the reference's per-row dynamic slices.
        ystart = jnp.clip(pj - (U - 1), 0, Lbuf - U)
        wstart = jnp.minimum(pj + 1, Lbuf - U)
        shift = (pj + 1) - wstart          # > 0 iff the tile spills
        t = rows - shift
        valid = t >= 0
        tclip = jnp.clip(t, 0, U - 1)
        for g in range(G):
            cs = conv_starts[g]
            y = a_refs[g][j, pl.ds(ystart, U), cs:cs + C].astype(_F32)
            o = _tau_block(y, rho_ref[g], U).astype(a_dtype).astype(_F32)
            if mode == "lcsm" and interpret:
                # Interpret mode runs the kernel body through XLA, whose
                # CPU fusion emitter contracts adjacent mul+add into one
                # FMA (1-ulp drift vs the reference; barriers/selects do
                # NOT stop it — measured).  The reference is immune
                # because its accumulate is a scatter, so mirror its
                # ``add_tile`` op-for-op: scatters never contract.
                oo = jnp.where(mj, o, 0.0)
                idx = pj + 1 + jnp.arange(U)
                oo = jnp.where((idx < Lbuf)[:, None], oo, 0.0)
                plane = out_refs[g][j, :, :]
                out_refs[g][j, :, :] = plane.at[
                    jnp.minimum(idx, Lbuf - 1)].add(oo)
                continue
            if mode == "lcsm":
                # Mosaic path (no scatter lowering): the same update as
                # an in-place clamped window + select — mask zeroes the
                # payload but the add still lands (+0.0 flips -0.0);
                # spilled outputs collapse onto row Lbuf-1 as zero-adds
                # (``lastdup``).  Mathematically identical to the
                # scatter; on-device bit-identity vs XLA is not promised
                # (it isn't for any hardware kernel).
                oo = jnp.where(mj, o, 0.0)
                contrib = jnp.take_along_axis(oo, tclip, axis=0)
                contrib = jnp.where(valid, contrib, 0.0)
                lastdup = (rows == U - 1) & (shift > 0)
                contrib = jnp.where(lastdup, contrib + 0.0, contrib)
                touched = valid | lastdup
            else:  # "select"
                contrib = jnp.take_along_axis(o, tclip, axis=0)
                touched = valid & mj
            bwin = out_refs[g][j, pl.ds(wstart, U), :]
            out_refs[g][j, pl.ds(wstart, U), :] = jnp.where(
                touched, bwin + contrib, bwin)


def gray_tile_apply(
    a_list: Sequence[jnp.ndarray],
    b_list: Sequence[jnp.ndarray],
    rho2u: jnp.ndarray,
    p: jnp.ndarray,
    mask: jnp.ndarray,
    *,
    conv_starts: Sequence[int],
    Lbuf: int,
    mode: str = "lcsm",
    slot_block: int = 1,
    interpret: bool = False,
) -> list[jnp.ndarray]:
    """Fused gray-tile apply for one conv-width group of G levels.

    a_list[g]: (B, Lbuf, W_g) activations; b_list[g]: (B, Lbuf, C) f32
    accumulators; rho2u: (G, 2U, C) f32 filter prefixes; p/mask: (B,)
    per-slot tile-end positions and selection mask.  Returns the G
    updated accumulators — contributions of a[p-U+1..p] to b[p+1..p+U],
    horizon-clipped, bitwise vs the XLA reference for ``mode``.
    """
    assert mode in ("lcsm", "select")
    G, twoU, C = rho2u.shape
    U = twoU // 2
    B = b_list[0].shape[0]
    assert len(a_list) == len(b_list) == len(conv_starts) == G
    assert B % slot_block == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B // slot_block,),
        in_specs=[
            *[pl.BlockSpec((slot_block, Lbuf, a.shape[-1]),
                           lambda i, pr, mr: (i, 0, 0)) for a in a_list],
            *[pl.BlockSpec((slot_block, Lbuf, C),
                           lambda i, pr, mr: (i, 0, 0)) for _ in b_list],
            pl.BlockSpec((G, twoU, C), lambda i, pr, mr: (0, 0, 0)),
        ],
        out_specs=[pl.BlockSpec((slot_block, Lbuf, C),
                                lambda i, pr, mr: (i, 0, 0))
                   for _ in b_list],
    )
    kern = functools.partial(
        _gray_kernel, G=G, U=U, Lbuf=Lbuf, C=C,
        conv_starts=tuple(conv_starts), slot_block=slot_block, mode=mode,
        a_dtype=a_list[0].dtype, interpret=interpret)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(b.shape, b.dtype) for b in b_list],
        # Operand order (p, mask, a_0.., b_0.., rho): alias b_g -> out_g.
        input_output_aliases={2 + G + g: g for g in range(G)},
        interpret=interpret,
    )(p.astype(jnp.int32), mask.astype(jnp.int32), *a_list, *b_list, rho2u)
    return list(out)


def _red_kernel(p_ref, a_ref, b_ref, rho0_ref, out_ref, *, Lbuf: int,
                C: int, conv_start: int, slot_block: int):
    """One slot-block of the red-cell FMA: out = b[p] + y[p]·rho_0."""
    i = pl.program_id(0)
    for j in range(slot_block):
        row = jnp.clip(p_ref[i * slot_block + j], 0, Lbuf - 1)
        y = a_ref[j, pl.ds(row, 1), conv_start:conv_start + C].astype(_F32)
        b = b_ref[j, pl.ds(row, 1), :]
        # Plain mul+add, matching the reference's op pattern exactly: XLA
        # CPU contracts BOTH into the same FMA (see _gray_kernel note).
        out_ref[j, :, :] = b + y * rho0_ref[...]


def red_pass_fma(
    a_l: jnp.ndarray,
    b_l: jnp.ndarray,
    rho0: jnp.ndarray,
    p: jnp.ndarray,
    *,
    conv_start: int = 0,
    slot_block: int = 1,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused red-cell gather+FMA for one level: (B, 1, C) f32
    ``b[p] + y[p]·rho_0`` — bitwise vs the reference's two dynamic
    slices + multiply-add.  a_l: (B, Lbuf, W); b_l: (B, Lbuf, C) f32;
    rho0: (C,) f32; p: (B,)."""
    B, Lbuf, W = a_l.shape
    C = b_l.shape[-1]
    assert B % slot_block == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // slot_block,),
        in_specs=[
            pl.BlockSpec((slot_block, Lbuf, W), lambda i, pr: (i, 0, 0)),
            pl.BlockSpec((slot_block, Lbuf, C), lambda i, pr: (i, 0, 0)),
            pl.BlockSpec((1, C), lambda i, pr: (0, 0)),
        ],
        out_specs=pl.BlockSpec((slot_block, 1, C), lambda i, pr: (i, 0, 0)),
    )
    kern = functools.partial(_red_kernel, Lbuf=Lbuf, C=C,
                             conv_start=conv_start, slot_block=slot_block)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, C), jnp.float32),
        interpret=interpret,
    )(p.astype(jnp.int32), a_l, b_l, rho0.reshape(1, C).astype(_F32))
