"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical definition, written with no regard for
performance; kernel tests sweep shapes/dtypes and assert_allclose against
these.
"""

from __future__ import annotations

import jax.numpy as jnp

_F32 = jnp.float32


def tile_conv_ref(y: jnp.ndarray, rho2u: jnp.ndarray) -> jnp.ndarray:
    """Direct τ tile (paper Lemma 1, square case).

    y: (..., U, C) — the U inputs ending at step i.
    rho2u: (..., 2U, C) — filter prefix rho[0 .. 2U-1] (broadcastable).
    out: (..., U, C) — out[t] = sum_s y[s] * rho[U + t - s], t,s in [0,U).
    """
    U = y.shape[-2]
    t = jnp.arange(U)
    idx = U + t[:, None] - t[None, :]  # (U, U) in [1, 2U-1]
    rmat = jnp.take(rho2u, idx, axis=-2)  # (..., U, U, C)
    return jnp.einsum(
        "...tsc,...sc->...tc", rmat, y, preferred_element_type=_F32
    ).astype(y.dtype)


def short_conv_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal FIR (Mamba conv1d / Hyena short filter).

    x: (B, T, C); w: (K, C) — tap d multiplies x[t - d]; b: (C,) or None.
    out: (B, T, C) with implicit zero left-padding.
    """
    K = w.shape[0]
    out = jnp.zeros(x.shape, _F32)
    for d in range(K):
        seg = jnp.pad(x, ((0, 0), (d, 0), (0, 0)))[:, : x.shape[1]]
        out = out + seg.astype(_F32) * w[d]
    if b is not None:
        out = out + b
    return out.astype(x.dtype)


def ssm_scan_ref(x, dt, A, B, C, D, h0=None):
    """Selective-SSM (Mamba-1) sequential oracle.

    x:  (Bt, T, C)   input (post short-conv, post silu)
    dt: (Bt, T, C)   softplus'd step sizes
    A:  (C, N)       negative-real diagonal (stored as raw; used as -exp? no —
                     caller passes the already-negative A)
    B:  (Bt, T, N)   input matrix (data-dependent)
    C:  (Bt, T, N)   output matrix (data-dependent)
    D:  (C,)         skip
    h0: (Bt, C, N)   initial state or None.
    Returns (y (Bt, T, C), h_T (Bt, C, N)).

    Discretization (Mamba ZOH-on-A, Euler-on-B):
      h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
      y_t = (C_t . h_t) + D * x_t
    """
    import jax

    Bt, T, Cdim = x.shape
    N = A.shape[1]
    h = jnp.zeros((Bt, Cdim, N), _F32) if h0 is None else h0.astype(_F32)
    ys = []
    for t in range(T):
        dta = dt[:, t, :, None].astype(_F32) * A[None]  # (Bt, C, N)
        h = jnp.exp(dta) * h + (
            dt[:, t, :, None] * x[:, t, :, None]
        ).astype(_F32) * B[:, t, None, :].astype(_F32)
        y = jnp.einsum("bcn,bn->bc", h, C[:, t].astype(_F32)) + D * x[:, t].astype(_F32)
        ys.append(y)
    del jax
    return jnp.stack(ys, axis=1).astype(x.dtype), h


def decode_attention_ref(q, k, v, pos):
    """Single-token GQA decode attention oracle.

    q: (B, Hkv, G, hd); k/v: (B, S, Hkv, hd); pos: (B,) valid lengths.
    out[b, h, g] = softmax_{s < pos_b}(q·k_s/√hd) · v.
    """
    import math

    B, K, G, hd = q.shape
    S = k.shape[1]
    lg = jnp.einsum("bkgh,bskh->bkgs", q.astype(_F32), k.astype(_F32))
    lg = lg / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] < pos[:, None]  # (B, S)
    lg = jnp.where(valid[:, None, None], lg, -1e30)
    w = jnp.exp(lg - lg.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    return jnp.einsum("bkgs,bskh->bkgh", w, v.astype(_F32)).astype(q.dtype)
