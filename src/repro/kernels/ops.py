"""Jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; everywhere else (this CPU
container, unit tests) they run in ``interpret=True`` mode, which executes
the kernel body in Python — same arithmetic, same BlockSpec pipelining
semantics, no Mosaic.  The flag is resolved from the backend once per
process and cached; tests that need to force a mode use
:func:`set_interpret_override` rather than monkeypatching the backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.short_conv import short_conv as _short_conv
from repro.kernels.tile_conv import tile_conv as _tile_conv

__all__ = ["tile_conv", "short_conv", "decode_attention", "gray_tile_apply",
           "red_pass_fma", "interpret_default", "set_interpret_override",
           "ref"]

ref = _ref

# Backend query, cached after the first call: jax.default_backend() walks
# the plugin registry per call, and the answer cannot change mid-process
# (jax pins the backend at first use).  ``None`` = not yet resolved.
_INTERPRET_CACHE: bool | None = None
# Test hook: a non-None override wins over the cached backend answer.
_INTERPRET_OVERRIDE: bool | None = None


def interpret_default() -> bool:
    global _INTERPRET_CACHE
    if _INTERPRET_OVERRIDE is not None:
        return _INTERPRET_OVERRIDE
    if _INTERPRET_CACHE is None:
        _INTERPRET_CACHE = jax.default_backend() != "tpu"
    return _INTERPRET_CACHE


def set_interpret_override(value: bool | None) -> bool | None:
    """Force (True/False) or restore (None) the interpret-mode default.

    Returns the previous override so tests can save/restore it."""
    global _INTERPRET_OVERRIDE
    prev = _INTERPRET_OVERRIDE
    _INTERPRET_OVERRIDE = value
    return prev


def tile_conv(y, rho2u, *, interpret: bool | None = None):
    """Direct τ tile via Pallas (see kernels/tile_conv.py, oracle ref.tile_conv_ref)."""
    itp = interpret_default() if interpret is None else interpret
    return _tile_conv(y, rho2u, interpret=itp)


# Bounded (FC005): block_t in principle follows the caller's sequence
# length, so an uncapped memo would grow one custom_vjp wrapper per
# distinct length a workload happens to contain.
@functools.lru_cache(maxsize=32)
def _short_conv_diffable(block_t: int, itp: bool):
    """custom_vjp wrapper: forward = Pallas kernel; backward = the exact
    transpose (an anti-causal FIR = time-flipped forward kernel + K small
    reductions for dw/db), so training paths (Mamba) can differentiate
    through the kernel."""

    @jax.custom_vjp
    def f(x, w, b):
        return _short_conv(x, w, b, block_t=block_t, interpret=itp)

    def fwd(x, w, b):
        return f(x, w, b), (x, w)

    def bwd(res, g):
        x, w = res
        T, K = x.shape[1], w.shape[0]
        # dx[t] = sum_d w[d] * g[t+d]  — run the same kernel on flipped time.
        gf = jnp.flip(g, axis=1)
        dxf = _short_conv(gf, w, None, block_t=block_t, interpret=itp)
        dx = jnp.flip(dxf, axis=1).astype(x.dtype)
        # dw[d] = sum_{b,t} g[t] * x[t-d]
        xs = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        dw = jnp.stack([
            jnp.einsum("btc,btc->c", g.astype(jnp.float32),
                       xs[:, K - 1 - d : K - 1 - d + T].astype(jnp.float32))
            for d in range(K)])
        db = jnp.sum(g.astype(jnp.float32), axis=(0, 1))
        return dx, dw.astype(w.dtype), db

    f.defvjp(fwd, bwd)
    return f


def short_conv(x, w, b=None, *, block_t: int = 128, interpret: bool | None = None):
    """Depthwise causal FIR via Pallas (oracle ref.short_conv_ref).

    Under an active mesh context (SPMD launch/dry-run) the jnp reference is
    used instead: the interpret-mode pallas_call is not partition-aware and
    GSPMD replicates its halo'd operands (measured 33 GiB/chip at
    falcon-mamba prefill).  On a real TPU backend the Mosaic kernel is
    partition-friendly under shard_map; interpret mode is a CPU stand-in.
    """
    from repro.models.components import sharding_ctx

    _, mesh = sharding_ctx()
    if mesh is not None:
        return _ref.short_conv_ref(x, w, b)
    itp = interpret_default() if interpret is None else interpret
    if b is None:
        b = jnp.zeros((x.shape[-1],), x.dtype)
    return _short_conv_diffable(block_t, itp)(x, w, b)


def gray_tile_apply(a_list, b_list, rho2u, p, mask, *, conv_starts,
                    Lbuf, mode="lcsm", slot_block=1,
                    interpret: bool | None = None):
    """Fused gray-tile conv + accumulate (see kernels/gray_tile.py; the
    XLA engine bodies are the bitwise-pinned oracles)."""
    from repro.kernels.gray_tile import gray_tile_apply as _gta

    itp = interpret_default() if interpret is None else interpret
    return _gta(a_list, b_list, rho2u, p, mask, conv_starts=conv_starts,
                Lbuf=Lbuf, mode=mode, slot_block=slot_block, interpret=itp)


def red_pass_fma(a_l, b_l, rho0, p, *, conv_start=0, slot_block=1,
                 interpret: bool | None = None):
    """Fused red-cell gather+FMA (see kernels/gray_tile.py)."""
    from repro.kernels.gray_tile import red_pass_fma as _rpf

    itp = interpret_default() if interpret is None else interpret
    return _rpf(a_l, b_l, rho0, p, conv_start=conv_start,
                slot_block=slot_block, interpret=itp)


def decode_attention(q, k, v, pos, *, chunk: int = 1024,
                     interpret: bool | None = None):
    """Flash decode attention via Pallas (oracle ref.decode_attention_ref)."""
    from repro.kernels.decode_attn import decode_attention as _da

    itp = interpret_default() if interpret is None else interpret
    return _da(q, k, v, pos, chunk=chunk, interpret=itp)
