"""Pallas TPU kernel: depthwise causal short FIR (Mamba conv1d, Hyena short
filter).

    out[b, t, c] = bias[c] + sum_{d=0}^{K-1} w[d, c] * x[b, t-d, c]

K is tiny (3–4), so the kernel is K shifted FMAs on the VPU.  Layout:
channels → 128-lane dim, time → sublane dim, time tiled by ``block_t``.
Causal history across time blocks is provided by materializing a halo'd
view of the input — each time block carries K-1 extra leading positions —
so programs stay independent (no cross-program communication).

VMEM per program: (2·block_t + K - 1) · 128 · 4 B ≈ 130 KiB at
block_t = 128; block_t is a tuning knob.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _short_conv_kernel(x_ref, w_ref, b_ref, out_ref, *, K: int, block_t: int):
    # x_ref: (block_t + K - 1, Cb) halo'd block; w_ref: (K, Cb);
    # b_ref: (1, Cb); out_ref: (block_t, Cb).
    x = x_ref[...].astype(jnp.float32)
    acc = jnp.broadcast_to(
        b_ref[0, :][None, :].astype(jnp.float32), (block_t, x.shape[1])
    )
    for d in range(K):
        # tap d multiplies x[t - d]; the halo puts output t=0 at row K-1.
        seg = jax.lax.slice_in_dim(x, K - 1 - d, K - 1 - d + block_t, axis=0)
        acc = acc + seg * w_ref[d, :][None, :].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def short_conv(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    *,
    block_t: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (B, T, C); w: (K, C); b: (C,) or None. Returns (B, T, C)."""
    B, T, C = x.shape
    K = w.shape[0]
    if b is None:
        b = jnp.zeros((C,), x.dtype)

    block_t = min(block_t, max(8, 1 << (T - 1).bit_length()))
    nT = (T + block_t - 1) // block_t
    Tp = nT * block_t
    Cp = max(_LANES, ((C + _LANES - 1) // _LANES) * _LANES)
    # causal left pad K-1 + right pad to the block grid + lane pad.
    xp = jnp.pad(x, ((0, 0), (K - 1, Tp - T), (0, Cp - C)))
    # Halo'd view: block i covers padded rows [i*block_t, i*block_t + block_t+K-1).
    starts = jnp.arange(nT) * block_t
    offs = jnp.arange(block_t + K - 1)
    xh = xp[:, starts[:, None] + offs[None, :], :]  # (B, nT, block_t+K-1, Cp)
    wp = jnp.pad(w, ((0, 0), (0, Cp - C)))
    bp = jnp.pad(b, ((0, Cp - C)))[None, :]  # (1, Cp)

    grid = (B, nT, Cp // _LANES)
    out = pl.pallas_call(
        functools.partial(_short_conv_kernel, K=K, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (None, None, block_t + K - 1, _LANES),
                lambda bi, ti, ci: (bi, ti, 0, ci),
            ),
            pl.BlockSpec((K, _LANES), lambda bi, ti, ci: (0, ci)),
            pl.BlockSpec((1, _LANES), lambda bi, ti, ci: (0, ci)),
        ],
        out_specs=pl.BlockSpec(
            (None, block_t, _LANES), lambda bi, ti, ci: (bi, ti, ci)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Tp, Cp), x.dtype),
        interpret=interpret,
    )(xh, wp, bp)
    return out[:, :T, :C]
