"""Tiling/occupancy chooser for the fused gray-tile Pallas path.

``tau_hybrid`` owns the §5.3 direct-vs-FFT crossover as a single scalar
(``direct_max``).  The fused gray-tile kernel (kernels/gray_tile.py) adds
two more degrees of freedom — how many serving slots ride in one kernel
program, and how many 128-lane blocks a conv-width occupies — so the
dispatch decision becomes a small *plan*, chosen here from power-of-two
candidates over (U, C, slot batch):

  * ``fused`` — use the fused kernel at all.  True exactly on the direct
    regime ``U <= min(direct_max, FUSED_MAX_U)``: the kernel's tile conv
    is the direct O(U²) form, so the FFT regime must keep the XLA body
    (which also keeps the fused path bitwise against the reference —
    tau_hybrid dispatches the identical direct arithmetic there).
  * ``slot_block`` — slots per kernel program: the largest power of two
    dividing the batch whose per-program VMEM working set (every level's
    a/b plane plus the shared filter block) stays under the budget, but
    never so large that the grid degenerates below ``min_programs``
    (TPU cores hide DMA latency by double-buffering across programs).
  * ``lane_block`` — the 128-lane-padded channel footprint used in the
    VMEM estimate (channels land on the lane dimension; a 5-wide conv
    still occupies one full 128-lane register row).

The ``FUSED_MAX_U`` ceiling is MEASURED, not guessed: benchmarks/
bench_tau.py times the fused kernel against the direct and FFT τ bodies
per U and writes the crossover into experiments/bench/BENCH_tau.json —
the committed table this constant mirrors (see README "τ dispatch").
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

_LANES = 128

# Measured ceiling for the fused direct-form kernel (BENCH_tau.json: the
# direct form stays on the Pareto frontier through U=32 on this backend
# and loses to the FFT body above it — the same knee tau_hybrid's default
# direct_max encodes).
FUSED_MAX_U = 32

# Per-program VMEM working-set budget.  ~16 MiB/core on current TPUs;
# stay at half so double-buffered pipelining fits (pallas guide).
VMEM_BUDGET_BYTES = 8 * 2**20

# Keep at least this many grid programs when shrinking the grid by
# batching slots, so the pipeline still overlaps DMA with compute.
MIN_PROGRAMS = 2


def _ceil_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def lane_blocks(C: int) -> int:
    """128-lane blocks a C-wide channel axis occupies."""
    return max(1, -(-C // _LANES))


@dataclass(frozen=True)
class GrayPlan:
    """One dispatch decision for a (U, group) gray-tile application."""

    fused: bool        # fused Pallas kernel vs the XLA reference body
    slot_block: int    # slots per kernel program (power of two, divides B)
    lane_block: int    # lane-padded channel footprint per plane (elements)
    reason: str        # why (for logs/benchmarks; not used in dispatch)


def gray_plan(
    *,
    U: int,
    C: int,
    batch: int,
    widths: Sequence[int],
    Lbuf: int,
    direct_max: int = 32,
    min_u: int = 1,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> GrayPlan:
    """Choose the dispatch plan for one conv-width group.

    ``widths`` are the a-plane channel widths of the group's levels (the
    b planes are all ``C`` wide).  All inputs are trace-time constants —
    the plan is static per (engine, U), like every other τ dispatch
    decision (§5.3: tile sides are powers of two known at trace time).

    ``min_u`` lets a caller floor the fused regime: the lcsm scatter path
    sets 2, because the U=1 tile degenerates to a bare multiply feeding
    the accumulate — exactly the shape XLA's CPU fusion emitter may
    contract to an FMA (rounding once, not twice) depending on the
    surrounding fusion context, which would break the bitwise pin against
    the reference body.  For U >= 2 the tile is a reduction (or the
    pinned reverse-FMA chain), which never contracts with the accumulate.
    """
    lane = lane_blocks(C) * _LANES
    fused_max = min(direct_max, FUSED_MAX_U)
    if U < min_u:
        return GrayPlan(False, 1, lane,
                        f"U={U} below fused floor (>= {min_u})")
    if U > fused_max:
        return GrayPlan(False, 1, lane,
                        f"U={U} beyond direct regime (<= {fused_max})")
    if U & (U - 1):
        return GrayPlan(False, 1, lane, f"U={U} not a power of two")
    if U > Lbuf:
        return GrayPlan(False, 1, lane, f"U={U} exceeds horizon {Lbuf}")

    # Per-slot VMEM bytes: every level's full a plane + b plane (the
    # kernel gathers/scatters with dynamic row windows, so whole planes
    # are resident), all lane-padded f32.
    per_slot = sum(lane_blocks(w) * _LANES + lane for w in widths)
    per_slot *= Lbuf * 4
    shared = len(widths) * 2 * U * lane * 4  # filter block, once
    slot_block = 1
    cand = 2
    while (cand <= batch and batch % cand == 0
           and batch // cand >= MIN_PROGRAMS
           and cand * per_slot + shared <= vmem_budget):
        slot_block = cand
        cand *= 2
    if slot_block * per_slot + shared > vmem_budget:
        return GrayPlan(False, 1, lane,
                        f"VMEM: {per_slot + shared} B/slot over budget")
    return GrayPlan(True, slot_block, lane,
                    f"direct regime, {slot_block} slot(s)/program")
