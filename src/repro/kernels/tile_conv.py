"""Pallas TPU kernel for the direct τ tile (paper §5.2 type-1, TPU-native).

The square gray tile of Algorithm 2 with side ``U`` computes, per channel,

    out[t, c] = sum_{s=0}^{U-1} y[s, c] * rho[U + t - s, c]      t in [0, U)

a *depthwise* banded convolution.  On GPU the paper uses cuDNN Conv1D /
FlashFFTConv; on TPU the depthwise form is VPU work, so the kernel is laid
out for the vector unit instead of the MXU:

  * channels on the 128-wide lane dimension (C tiled by 128),
  * the U time steps on the sublane dimension,
  * the inner reduction unrolled as U shifted fused multiply-adds, each an
    (U, 128) elementwise FMA reading a length-U sliding window of ``rho``.

VMEM working set per program: y (U,128) + rho (2U,128) + out (U,128) ≈
4U·128 · 4 B — even U=512 is ~1 MiB, far below the ~16 MiB/core budget, so
no further time tiling is needed (the hybrid dispatcher routes U > ~64 to
the FFT path anyway).

Leading (group/batch) dims are flattened onto the grid's first axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _tile_conv_kernel(y_ref, rho_ref, out_ref, *, U: int):
    """One (U, Cb) output block.

    y_ref: (U, Cb); rho_ref: (2U, Cb); out_ref: (U, Cb).
    out[t] = sum_s y[s] * rho[U + t - s]
           = sum_s y[s] * rev_window_s[t],  rev_window_s = rho[U-s : 2U-s].
    """
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    rho = rho_ref[...].astype(jnp.float32)
    # Unrolled: U is a trace-time constant (tile sides are powers of two and
    # the hybrid dispatcher keeps the Pallas path to small U), so the slice
    # starts are static — no dynamic-slice lowering needed.
    for s in range(U):
        window = jax.lax.slice_in_dim(rho, U - s, 2 * U - s, axis=0)  # (U, Cb)
        acc = acc + y[s, :][None, :] * window
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tile_conv(y: jnp.ndarray, rho2u: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Pallas direct τ. y: (..., U, C); rho2u: (..., 2U, C) broadcastable.

    Returns (..., U, C), same dtype as y.
    """
    U, C = y.shape[-2], y.shape[-1]
    if rho2u.shape[-2] != 2 * U:
        raise ValueError(f"rho2u must have length 2U={2*U}, got {rho2u.shape[-2]}")
    lead = y.shape[:-2]
    nb = 1
    for d in lead:
        nb *= d
    y2 = y.reshape(nb, U, C)
    # A filter with no (or all-unit) leading dims is *shared* across the
    # batch grid axis.  Materializing nb copies via broadcast_to would blow
    # the HBM footprint from O(U·C) to O(nb·U·C) and re-stream the same
    # bytes once per grid program; instead keep a single copy and point
    # every program's rho BlockSpec at block row 0.
    shared_rho = all(d == 1 for d in rho2u.shape[:-2])
    if shared_rho:
        rho2 = rho2u.reshape(1, 2 * U, C)
        rho_index = lambda b, c: (0, 0, c)
    else:
        rho2 = jnp.broadcast_to(rho2u, lead + (2 * U, C)).reshape(nb, 2 * U, C)
        rho_index = lambda b, c: (b, 0, c)

    # Pad channels up to the lane width so every block is (., 128)-aligned.
    Cp = max(_LANES, ((C + _LANES - 1) // _LANES) * _LANES)
    if Cp != C:
        y2 = jnp.pad(y2, ((0, 0), (0, 0), (0, Cp - C)))
        rho2 = jnp.pad(rho2, ((0, 0), (0, 0), (0, Cp - C)))

    grid = (nb, Cp // _LANES)
    out = pl.pallas_call(
        functools.partial(_tile_conv_kernel, U=U),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, U, _LANES), lambda b, c: (b, 0, c)),
            pl.BlockSpec((None, 2 * U, _LANES), rho_index),
        ],
        out_specs=pl.BlockSpec((None, U, _LANES), lambda b, c: (b, 0, c)),
        out_shape=jax.ShapeDtypeStruct((nb, U, Cp), y.dtype),
        interpret=interpret,
    )(y2, rho2)
    if Cp != C:
        out = out[..., :C]
    return out.reshape(lead + (U, C))
