"""Pallas TPU kernel: single-token GQA decode attention (flash-style).

    out[b, k, g, :] = softmax_s( q[b,k,g]·K[b,s,k] / √hd  | s < pos_b ) · V

The decode roofline floor is reading the KV cache once; this kernel
streams the cache through VMEM in sequence chunks with an online-softmax
accumulator in scratch, so HBM traffic = cache bytes + O(1):

  * grid = (B, Hkv, S/chunk) — the chunk axis is minor-most, so scratch
    (m, l, acc) carries across it; outputs are written on the last chunk.
  * blocks: K/V (1, chunk, 1, hd) → VMEM ≈ 2·chunk·hd·2B (≈0.5 MiB at
    chunk=1024, hd=128); q/out (1, 1, G, hd) are tiny.
  * per-row validity: positions ≥ pos_b are masked to -inf (ring-buffer
    caches pass pos = min(pos+1, S), full caches pos+1).

The jnp serving path (models/attention.gqa_decode) remains the SPMD
reference; this kernel is the TPU hot-spot artifact, validated against
ref.decode_attention_ref in interpret mode across shape/dtype sweeps.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_F32 = jnp.float32
_NEG = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, chunk: int, hd: int, n_chunks: int):
    # None block dims are squeezed: q_ref/o_ref (G, hd); k_ref/v_ref
    # (chunk, hd); pos_ref (1,).  scratch: m/l (G, 1), acc (G, hd) —
    # persists across the minor-most (chunk) grid axis.
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(_F32)                       # (G, hd)
    k = k_ref[...].astype(_F32)                       # (chunk, hd)
    v = v_ref[...].astype(_F32)
    lg = jnp.dot(q, k.T) * (1.0 / math.sqrt(hd))      # (G, chunk)
    spos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
    valid = spos < pos_ref[...]
    lg = jnp.where(valid, lg, _NEG)

    m_old = m_ref[...]                                # (G, 1)
    m_new = jnp.maximum(m_old, lg.max(axis=1, keepdims=True))
    p = jnp.exp(lg - m_new)                           # (G, chunk)
    resc = jnp.exp(m_old - m_new)                     # (G, 1)
    l_ref[...] = l_ref[...] * resc + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * resc + jnp.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def decode_attention(q, k, v, pos, *, chunk: int = 1024,
                     interpret: bool = False):
    """q: (B, Hkv, G, hd); k/v: (B, S, Hkv, hd); pos: (B,) valid lengths.
    Returns (B, Hkv, G, hd) in q's dtype."""
    B, K, G, hd = q.shape
    S = k.shape[1]
    chunk = min(chunk, max(8, S))
    nc = -(-S // chunk)
    Sp = nc * chunk
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    pos2 = pos.reshape(B, 1).astype(jnp.int32)

    grid = (B, K, nc)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, chunk=chunk, hd=hd, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None), lambda b, h, c: (b, 0)),
            pl.BlockSpec((None, None, G, hd), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((None, chunk, None, hd), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((None, chunk, None, hd), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, G, hd), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), _F32),
            pltpu.VMEM((G, 1), _F32),
            pltpu.VMEM((G, hd), _F32),
        ],
        interpret=interpret,
    )(pos2, q, k, v)
    return out
