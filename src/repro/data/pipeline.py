"""Deterministic synthetic LM data pipeline.

The paper's experiments run on randomly-initialized weights (runtime is the
object of study), so the data path only needs to be *deterministic,
shardable and shaped like real data*.  We generate token streams from a
fixed-seed Markov-ish hash chain (cheap, reproducible across hosts, no
collective needed: every host computes its own shard by index).

Batches follow the model-family input contracts of models/lm.py:
  text  : tokens, targets
  audio : + enc_frames (precomputed mel-frame embeddings — stub frontend)
  vlm   : + vis_embed, pos3 (precomputed patch embeddings — stub frontend)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def _hash_tokens(seed: int, start: int, n: int, vocab: int) -> np.ndarray:
    """Deterministic pseudo-token stream; position-addressable (no state),
    so any (host, step) slice is computable independently."""
    idx = (start + np.arange(n, dtype=np.uint64)) * np.uint64(6364136223846793005)
    idx ^= np.uint64(seed) * np.uint64(1442695040888963407)
    idx ^= idx >> np.uint64(33)
    idx *= np.uint64(0xFF51AFD7ED558CCD)
    idx ^= idx >> np.uint64(33)
    return (idx % np.uint64(max(vocab - 1, 1))).astype(np.int32)


@dataclass
class SyntheticLMDataset:
    """Per-host deterministic batches.

    ``host_id``/``n_hosts`` slice the global batch: host h owns rows
    [h*B/n_hosts, (h+1)*B/n_hosts) — the same protocol a real multi-host
    loader would follow (each host feeds its addressable devices).
    """

    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    n_vis: int = 0  # VLM: patch-embedding prefix length

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        B, T = self.host_batch, self.seq_len
        row0 = step * self.global_batch + self.host_id * B
        toks = np.stack([
            _hash_tokens(self.seed, (row0 + i) << 22, T + 1, cfg.vocab)
            for i in range(B)
        ])
        out = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:]),
        }
        if cfg.enc_layers:
            frames = _hash_tokens(self.seed + 1, row0 << 22,
                                  B * cfg.enc_positions * cfg.d_model, 1 << 16)
            out["enc_frames"] = (
                jnp.asarray(frames, jnp.float32).reshape(
                    B, cfg.enc_positions, cfg.d_model) / (1 << 15) - 1.0) * 0.02
        if cfg.m_rope and self.n_vis:
            emb = _hash_tokens(self.seed + 2, (row0 + 7) << 22,
                               B * self.n_vis * cfg.d_model, 1 << 16)
            out["vis_embed"] = (
                jnp.asarray(emb, jnp.float32).reshape(B, self.n_vis, cfg.d_model)
                / (1 << 15) - 1.0) * 0.02
            out["pos3"] = vlm_pos3(B, self.n_vis, T)
        return out


def vlm_pos3(B: int, n_vis: int, T_text: int) -> jnp.ndarray:
    """M-RoPE position ids for a [vis | text] sequence: visual patches get a
    (t=0, h, w) grid; text continues with equal (t, h, w) after the grid."""
    side = max(1, int(np.sqrt(n_vis)))
    hh = (np.arange(n_vis) // side).astype(np.int32)
    ww = (np.arange(n_vis) % side).astype(np.int32)
    tt = np.zeros(n_vis, np.int32)
    t0 = int(hh.max(initial=0)) + 1
    text = t0 + np.arange(T_text, dtype=np.int32)
    pos = np.stack([
        np.concatenate([tt, text]), np.concatenate([hh, text]),
        np.concatenate([ww, text])])
    return jnp.broadcast_to(jnp.asarray(pos)[:, None, :], (3, B, n_vis + T_text))


def make_batch_specs(cfg: ModelConfig, global_batch: int, seq_len: int,
                     n_vis: int = 0) -> dict:
    """ShapeDtypeStruct stand-ins mirroring ``SyntheticLMDataset.batch`` —
    used by the dry-run (no allocation)."""
    B, T = global_batch, seq_len
    sds = jax.ShapeDtypeStruct
    out = {
        "tokens": sds((B, T), jnp.int32),
        "targets": sds((B, T), jnp.int32),
    }
    if cfg.enc_layers:
        out["enc_frames"] = sds((B, cfg.enc_positions, cfg.d_model), jnp.float32)
    if cfg.m_rope and n_vis:
        out["vis_embed"] = sds((B, n_vis, cfg.d_model), jnp.float32)
        out["pos3"] = sds((3, B, n_vis + T), jnp.int32)
    return out
