from repro.data.pipeline import SyntheticLMDataset, make_batch_specs  # noqa: F401
