"""The paper's §5 synthetic LCSM: M mixer levels, MLP blocks (hidden 2D,
GELU), advance = a_M + noise (a stand-in sampler so vocabulary size is out of
scope, exactly as in the paper)."""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.engine import LevelSpec
from repro.models import components as C


class SyntheticLCSM:
    """Engine-compatible synthetic model (see repro.core.engine.LCSMModel)."""

    ctx_window = 0

    def __init__(self, n_levels: int, d_model: int, *, filter_decay: float = 0.02,
                 mlp_mult: int = 2):
        self.M = n_levels
        self.d = d_model
        self.a0_width = d_model
        self.mlp_mult = mlp_mult
        self.filter_decay = filter_decay
        self.levels: Sequence[LevelSpec] = tuple(
            LevelSpec(width=d_model, conv_start=0, conv_size=d_model)
            for _ in range(n_levels)
        )

    def init(self, key) -> Any:
        keys = jax.random.split(key, self.M + 1)
        return {
            "filter_key": jax.random.key_data(keys[0]),
            "blocks": [
                C.init_mlp_gelu(keys[1 + l], self.d, self.mlp_mult * self.d)
                for l in range(self.M)
            ],
        }

    def filters(self, params, length: int):
        key = jax.random.wrap_key_data(params["filter_key"])
        raw = jax.random.normal(key, (self.M, length, self.d), jnp.float32)
        t = jnp.arange(length, dtype=jnp.float32)
        decay = jnp.exp(-self.filter_decay * t)[None, :, None]
        rho = raw * decay / jnp.sqrt(1.0 + t)[None, :, None]
        return [rho[l] for l in range(self.M)]

    def block(self, params, level: int, b: jnp.ndarray,
              acts: Sequence[jnp.ndarray]) -> jnp.ndarray:
        del acts
        return b + C.mlp_gelu(params["blocks"][level], b)

    def advance(self, params, acts: Sequence[jnp.ndarray], rng) -> tuple:
        top = acts[self.M][:, -1]  # (B, D) — just-finalized a_M
        noise = 0.01 * jax.random.normal(rng, top.shape, top.dtype)
        nxt = jnp.tanh(top) + noise
        token = jnp.zeros((top.shape[0],), jnp.int32)
        return nxt, token
