"""Layer assembly: (mixer, ffn) pairs per configs.base.LayerDef.

Pre-norm residual blocks:  x += mixer(norm1(x));  x += ffn(norm2(x)).
Every function is functional (params pytree in, arrays out) and works both
under a python loop and under jax.lax.scan over a stacked leading axis.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerDef, ModelConfig
from repro.models import attention as A
from repro.models import components as C
from repro.models import mamba as S
from repro.models import moe as E

_F32 = jnp.float32


def _init_norm(cfg: ModelConfig, d: int):
    if cfg.norm == "rms":
        return {"w": jnp.ones((d,), _F32)}
    return {"w": jnp.ones((d,), _F32), "b": jnp.zeros((d,), _F32)}


def _apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rms":
        return C.rms_norm(x, p["w"])
    return C.layer_norm(x, p["w"], p["b"])


# ------------------------------------------------------------------- init
def init_layer(key, cfg: ModelConfig, ld: LayerDef) -> dict:
    k_mix, k_ffn = jax.random.split(key)
    p: dict[str, Any] = {"norm1": _init_norm(cfg, cfg.d_model)}
    if ld.mixer == "attn":
        p["attn"] = A.init_gqa(k_mix, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, qkv_bias=cfg.qkv_bias)
    elif ld.mixer == "attn_cross":
        k1, k2 = jax.random.split(k_mix)
        p["attn"] = A.init_gqa(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, qkv_bias=cfg.qkv_bias)
        p["cross"] = A.init_cross(k2, cfg.d_model, cfg.n_heads, cfg.head_dim)
        p["norm_c"] = _init_norm(cfg, cfg.d_model)
    elif ld.mixer == "mla":
        p["mla"] = A.init_mla(k_mix, cfg.d_model, cfg.n_heads,
                              q_lora=cfg.q_lora, kv_lora=cfg.kv_lora,
                              rope_dim=cfg.rope_dim, head_dim=cfg.head_dim,
                              v_head_dim=cfg.v_head_dim)
    elif ld.mixer == "mamba":
        p["mamba"] = S.init_mamba(k_mix, cfg.d_model, d_inner=cfg.d_inner,
                                  N=cfg.ssm_state, K=cfg.conv_k)
    else:
        raise ValueError(ld.mixer)

    if ld.ffn == "dense":
        p["norm2"] = _init_norm(cfg, cfg.d_model)
        if cfg.family == "audio":
            p["mlp"] = C.init_mlp_gelu(k_ffn, cfg.d_model, cfg.d_ff)
        else:
            p["mlp"] = C.init_swiglu(k_ffn, cfg.d_model, cfg.d_ff)
    elif ld.ffn == "moe":
        p["norm2"] = _init_norm(cfg, cfg.d_model)
        p["moe"] = E.init_moe(k_ffn, cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                              top_k=cfg.top_k, n_shared=cfg.n_shared_experts,
                              shared_d_ff=cfg.moe_d_ff)
    return p


# ------------------------------------------------------------------ caches
class LayerCache(NamedTuple):
    """Union cache: exactly one member populated per mixer kind (the other
    is a zero-size placeholder so scan pytrees stay uniform per stack)."""

    kv: Any
    ssm: Any
    cross: Any


def _zero_kv(cfg, batch: int, S: int, dtype) -> A.KVCache:
    return A.KVCache(
        k=jnp.zeros((batch, S, cfg.n_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, S, cfg.n_kv_heads, cfg.head_dim), dtype),
        pos=jnp.zeros((batch,), jnp.int32))


def init_layer_cache(cfg: ModelConfig, ld: LayerDef, batch: int, S_cap: int,
                     dtype=jnp.bfloat16, enc_S: int = 0,
                     window: int | None = None) -> LayerCache:
    kv = ssm = cross = ()
    if ld.mixer == "attn":
        win = window or cfg.sliding_window
        cap = min(S_cap, win) if win else S_cap
        kv = _zero_kv(cfg, batch, cap, dtype)
    elif ld.mixer == "attn_cross":
        kv = _zero_kv(cfg, batch, S_cap, dtype)
        cross = (jnp.zeros((batch, enc_S, cfg.n_heads, cfg.head_dim), dtype),
                 jnp.zeros((batch, enc_S, cfg.n_heads, cfg.head_dim), dtype))
    elif ld.mixer == "mla":
        win = window or cfg.sliding_window
        cap = min(S_cap, win) if win else S_cap
        kv = A.MLACache(
            ckv=jnp.zeros((batch, cap, cfg.kv_lora), dtype),
            k_rope=jnp.zeros((batch, cap, cfg.rope_dim), dtype),
            pos=jnp.zeros((batch,), jnp.int32))
    elif ld.mixer == "mamba":
        d_inner = cfg.d_inner or 2 * cfg.d_model
        ssm = S.init_mamba_state(batch, d_inner, cfg.ssm_state, cfg.conv_k, dtype)
    return LayerCache(kv=kv, ssm=ssm, cross=cross)


# ------------------------------------------------------------------- apply
def _mixer_train(p, cfg: ModelConfig, ld: LayerDef, x, aux_in: dict):
    freqs = A.rope_freqs(cfg.rope_dim if ld.mixer == "mla" else cfg.head_dim,
                         cfg.rope_theta)
    if ld.mixer == "attn":
        return A.gqa_train(
            p["attn"], x, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, freqs=freqs, window=aux_in.get("window"),
            m_rope_pos=aux_in.get("pos3") if cfg.m_rope else None,
            m_rope_sections=cfg.m_rope_sections)
    if ld.mixer == "mla":
        return A.mla_train(p["mla"], x, n_heads=cfg.n_heads, head_dim=cfg.head_dim,
                           rope_dim=cfg.rope_dim, kv_lora=cfg.kv_lora,
                           v_head_dim=cfg.v_head_dim or cfg.head_dim, freqs=freqs)
    if ld.mixer == "mamba":
        return S.mamba_train(p["mamba"], x, N=cfg.ssm_state)
    raise ValueError(ld.mixer)


def apply_layer_train(p, cfg: ModelConfig, ld: LayerDef, x, aux_in: dict):
    """Returns (x', moe_aux)."""
    h = _apply_norm(cfg, p["norm1"], x)
    if ld.mixer == "attn_cross":
        freqs = A.rope_freqs(cfg.head_dim, cfg.rope_theta)
        y = A.gqa_train(p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                        head_dim=cfg.head_dim, freqs=freqs)
        x = x + y
        hc = _apply_norm(cfg, p["norm_c"], x)
        x = x + A.cross_attention(p["cross"], hc, aux_in["enc_out"],
                                  n_heads=cfg.n_heads, head_dim=cfg.head_dim)
    else:
        x = x + _mixer_train(p, cfg, ld, h, aux_in)
    aux = jnp.zeros((), _F32)
    if ld.ffn == "dense":
        h = _apply_norm(cfg, p["norm2"], x)
        f = C.mlp_gelu(p["mlp"], h) if cfg.family == "audio" else C.swiglu(p["mlp"], h)
        x = x + f
    elif ld.ffn == "moe":
        h = _apply_norm(cfg, p["norm2"], x)
        f, aux = E.moe_ffn(p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                           capacity_factor=cfg.moe_capacity_factor,
                           group_size=cfg.moe_group_size)
        x = x + f
    return x, aux


def apply_layer_decode(p, cfg: ModelConfig, ld: LayerDef, x, cache: LayerCache,
                       aux_in: dict):
    """x: (B, 1, D). Returns (x', new cache)."""
    freqs = A.rope_freqs(cfg.rope_dim if ld.mixer == "mla" else cfg.head_dim,
                         cfg.rope_theta)
    h = _apply_norm(cfg, p["norm1"], x)
    kv, ssm, cross = cache
    if ld.mixer == "attn":
        y, kv = A.gqa_decode(
            p["attn"], h, kv, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, freqs=freqs, window=aux_in.get("window"),
            m_rope_pos=aux_in.get("pos3") if cfg.m_rope else None,
            m_rope_sections=cfg.m_rope_sections)
    elif ld.mixer == "attn_cross":
        y, kv = A.gqa_decode(p["attn"], h, kv, n_heads=cfg.n_heads,
                             n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                             freqs=freqs)
        xc = x + y
        ck, cv = cross
        hc = _apply_norm(cfg, p["norm_c"], xc)
        yc = _cross_decode(p["cross"], hc, ck, cv, n_heads=cfg.n_heads,
                           head_dim=cfg.head_dim)
        y = y + yc
    elif ld.mixer == "mla":
        y, kv = A.mla_decode(p["mla"], h, kv, n_heads=cfg.n_heads,
                             head_dim=cfg.head_dim, rope_dim=cfg.rope_dim,
                             kv_lora=cfg.kv_lora,
                             v_head_dim=cfg.v_head_dim or cfg.head_dim,
                             freqs=freqs, window=aux_in.get("window"))
    elif ld.mixer == "mamba":
        y, ssm = S.mamba_decode(p["mamba"], h, ssm, N=cfg.ssm_state)
    else:
        raise ValueError(ld.mixer)
    x = x + y
    aux = jnp.zeros((), _F32)
    if ld.ffn == "dense":
        h = _apply_norm(cfg, p["norm2"], x)
        f = C.mlp_gelu(p["mlp"], h) if cfg.family == "audio" else C.swiglu(p["mlp"], h)
        x = x + f
    elif ld.ffn == "moe":
        h = _apply_norm(cfg, p["norm2"], x)
        f, aux = E.moe_ffn(p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                           capacity_factor=cfg.moe_capacity_factor,
                           group_size=cfg.moe_group_size)
        x = x + f
    return x, LayerCache(kv=kv, ssm=ssm, cross=cross)


def _cross_decode(p, x, ck, cv, *, n_heads, head_dim):
    out = A._sdpa(A._proj(p["wq"], x, n_heads, head_dim), ck, cv, None, n_heads)
    B = x.shape[0]
    y = jnp.einsum("btf,fd->btd", out.reshape(B, 1, -1), p["wo"]["w"],
                   preferred_element_type=_F32)
    return y.astype(x.dtype)


def prefill_layer_cache(p, cfg: ModelConfig, ld: LayerDef, x, S_cap: int,
                        aux_in: dict, dtype=jnp.bfloat16) -> LayerCache:
    """Build the post-prompt cache from a full-sequence forward's inputs.
    x is the *normed* mixer input (B, T, D); T <= S_cap."""
    B, T, _ = x.shape
    kv = ssm = cross = ()
    freqs = A.rope_freqs(cfg.rope_dim if ld.mixer == "mla" else cfg.head_dim,
                         cfg.rope_theta)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if ld.mixer in ("attn", "attn_cross"):
        k = A._proj(p["attn"]["wk"], x, cfg.n_kv_heads, cfg.head_dim)
        v = A._proj(p["attn"]["wv"], x, cfg.n_kv_heads, cfg.head_dim)
        if cfg.m_rope and aux_in.get("pos3") is not None:
            k = A.apply_m_rope(k, aux_in["pos3"], freqs, cfg.m_rope_sections)
        else:
            k = A.apply_rope(k, pos, freqs)
        window = aux_in.get("window")
        cap = min(S_cap, window) if window else S_cap
        kvc = _zero_kv(cfg, B, cap, dtype)
        take = min(T, cap)
        kv = A.KVCache(
            k=kvc.k.at[:, :take].set(k[:, -take:].astype(dtype)),
            v=kvc.v.at[:, :take].set(v[:, -take:].astype(dtype)),
            pos=jnp.full((B,), T, jnp.int32))
        if ld.mixer == "attn_cross":
            enc = aux_in["enc_out"]
            ck = A._proj(p["cross"]["wk"], enc, cfg.n_heads, cfg.head_dim)
            cv = A._proj(p["cross"]["wv"], enc, cfg.n_heads, cfg.head_dim)
            cross = (ck.astype(dtype), cv.astype(dtype))
    elif ld.mixer == "mla":
        kvp = jnp.einsum("btd,df->btf", x, p["mla"]["wkv_a"]["w"],
                         preferred_element_type=_F32)
        ckv, k_rope = kvp[..., : cfg.kv_lora], kvp[..., cfg.kv_lora :]
        k_rope = A.apply_rope(k_rope[:, :, None].astype(x.dtype), pos, freqs)[:, :, 0]
        base = A.MLACache(
            ckv=jnp.zeros((B, S_cap, cfg.kv_lora), dtype),
            k_rope=jnp.zeros((B, S_cap, cfg.rope_dim), dtype),
            pos=jnp.full((B,), T, jnp.int32))
        kv = A.MLACache(ckv=base.ckv.at[:, :T].set(ckv.astype(dtype)),
                        k_rope=base.k_rope.at[:, :T].set(k_rope.astype(dtype)),
                        pos=base.pos)
    elif ld.mixer == "mamba":
        d_inner = cfg.d_inner or 2 * cfg.d_model
        xz = jnp.einsum("btd,df->btf", x, p["mamba"]["in_proj"]["w"],
                        preferred_element_type=_F32).astype(x.dtype)
        _, h_T = S.mamba_prefill_state(p["mamba"], xz, N=cfg.ssm_state)
        xs = xz[..., :d_inner]
        K = cfg.conv_k
        tail = xs[:, -(K - 1):].astype(dtype)
        pad = jnp.zeros((B, max(0, K - 1 - T), d_inner), dtype)
        ssm = S.MambaState(conv=jnp.concatenate([pad, tail], 1)[:, -(K - 1):], ssm=h_T)
    return LayerCache(kv=kv, ssm=ssm, cross=cross)
