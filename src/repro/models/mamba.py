"""Mamba-1 selective SSM mixer (falcon-mamba / Jamba Mamba layers).

Train/prefill path: depthwise short conv (Pallas kernel) + selective scan
via ``jax.lax.associative_scan`` over the (decay, increment) monoid —
O(T log T) work, parallel over (batch, channel, state) — the TPU-native
replacement for the CUDA selective-scan kernel.

Decode path: O(1)/token recurrence on carried (conv window, SSM state).
This is exactly the "RNN-like" inference the paper contrasts against
(§2.3.2) — kept as the native decode for SSM archs, per DESIGN §4.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.components import constrain, init_dense

_F32 = jnp.float32


class MambaState(NamedTuple):
    conv: jnp.ndarray  # (B, K-1, d_inner) — trailing conv window
    ssm: jnp.ndarray   # (B, d_inner, N)   — recurrent state


def init_mamba(key, d_model: int, *, d_inner: int | None = None, N: int = 16,
               K: int = 4, dt_rank: int | None = None, dtype=_F32):
    d_inner = 2 * d_model if d_inner is None else d_inner
    dt_rank = max(1, d_model // 16) if dt_rank is None else dt_rank
    ks = jax.random.split(key, 7)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=_F32)[None], (d_inner, 1))
    return {
        "in_proj": init_dense(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (K, d_inner), _F32) / K).astype(_F32),
        "conv_b": jnp.zeros((d_inner,), _F32),
        "x_proj": init_dense(ks[2], d_inner, dt_rank + 2 * N, dtype=dtype),
        "dt_proj": init_dense(ks[3], dt_rank, d_inner, bias=True, dtype=_F32),
        "A_log": jnp.log(A),          # (d_inner, N); A = -exp(A_log)
        "D": jnp.ones((d_inner,), _F32),
        "out_proj": init_dense(ks[4], d_inner, d_model, dtype=dtype),
    }


def _ssm_inputs(p, xz, *, N: int):
    """Common path: split/conv/activations -> (x, z, dt, B_, C_)."""
    d_inner = p["A_log"].shape[0]
    x, z = jnp.split(xz, 2, axis=-1)  # (B, T, d_inner) each
    x = kops.short_conv(x, p["conv_w"], p["conv_b"])
    x = constrain(jax.nn.silu(x.astype(_F32)))
    proj = jnp.einsum("btd,df->btf", x, p["x_proj"]["w"].astype(_F32))
    dt_rank = proj.shape[-1] - 2 * N
    dt, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = constrain(jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt, p["dt_proj"]["w"].astype(_F32))
        + p["dt_proj"]["b"]))
    return x, z, dt, B_, C_


def _scan_monoid(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def mamba_train(p, u, *, N: int = 16, chunk: int = 128):
    """u: (B, T, D) -> (B, T, D).

    Chunked parallel scan: a sequential lax.scan over T/chunk blocks carrying
    the (B, C, N) state, with an associative scan *inside* each block.  Peak
    memory is O(B·chunk·C·N) instead of O(B·T·C·N) — the full-length
    associative scan materializes (decay, inc) over all T positions, which
    at falcon-mamba scale (d_inner 8192, T 4096) is terabytes.  The TPU
    analogue of the fused CUDA selective-scan kernel's chunking.
    """
    B, T, D = u.shape
    xz = jnp.einsum("btd,df->btf", u, p["in_proj"]["w"],
                    preferred_element_type=_F32).astype(u.dtype)
    x, z, dt, B_, C_ = _ssm_inputs(p, xz, N=N)
    A = -jnp.exp(p["A_log"])  # (d_inner, N)
    Cdim = A.shape[0]

    chunk = min(chunk, T)
    if T % chunk:  # pad time to a whole number of chunks (dt=0 => identity)
        pad = chunk - T % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    def reblk(a):  # (B, T, F) -> (nc, B, chunk, F)
        return a.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def body(h0, xs):
        # checkpointed: the backward recomputes this chunk's (B, Q, C, N)
        # decay/inc instead of saving them for every chunk.
        xb, dtb, Bb, Cb = xs  # (B, chunk, ·)
        decay = jnp.exp(dtb[..., None] * A)                 # (B, Q, C, N)
        inc = (dtb * xb)[..., None] * Bb[:, :, None, :]
        cumdecay, hrel = jax.lax.associative_scan(_scan_monoid, (decay, inc), axis=1)
        h = cumdecay * h0[:, None] + hrel                    # (B, Q, C, N)
        yb = jnp.einsum("btcn,btn->btc", h, Cb)
        return h[:, -1], yb

    h0 = jnp.zeros((B, Cdim, N), _F32)
    _, ys = jax.lax.scan(body, h0, (reblk(x), reblk(dt), reblk(B_), reblk(C_)))
    y = constrain(ys.transpose(1, 0, 2, 3).reshape(B, -1, Cdim)[:, :T])
    y = y + p["D"] * x[:, :T]
    y = y * jax.nn.silu(z.astype(_F32))
    out = jnp.einsum("btc,cd->btd", y, p["out_proj"]["w"].astype(_F32))
    return out.astype(u.dtype)


def mamba_prefill_state(p, xz, *, N: int = 16, chunk: int = 128):
    """Final SSM state after ingesting xz (B, T, 2*d_inner) — for prefill.
    Returns (None, h_T (B, d_inner, N) f32).  Chunked like mamba_train."""
    x, _, dt, B_, _ = _ssm_inputs(p, xz, N=N)
    A = -jnp.exp(p["A_log"])
    B, T, Cdim = x.shape
    chunk = min(chunk, T)
    if T % chunk:
        pad = chunk - T % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    def reblk(a):
        return a.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)

    def body(h0, xs):
        xb, dtb, Bb = xs
        decay = jnp.exp(dtb[..., None] * A)
        inc = (dtb * xb)[..., None] * Bb[:, :, None, :]
        cumdecay, hrel = jax.lax.associative_scan(_scan_monoid, (decay, inc), axis=1)
        return cumdecay[:, -1] * h0 + hrel[:, -1], None

    h0 = jnp.zeros((B, Cdim, N), _F32)
    hT, _ = jax.lax.scan(body, h0, (reblk(x), reblk(dt), reblk(B_)))
    return None, hT


def init_mamba_state(batch: int, d_inner: int, N: int, K: int, dtype=_F32) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, K - 1, d_inner), dtype),
        ssm=jnp.zeros((batch, d_inner, N), _F32),
    )


def mamba_decode(p, u, state: MambaState, *, N: int = 16):
    """u: (B, 1, D); O(1) recurrent step. Returns (y (B,1,D), new state)."""
    xz = jnp.einsum("btd,df->btf", u, p["in_proj"]["w"],
                    preferred_element_type=_F32).astype(u.dtype)
    x, z = jnp.split(xz, 2, axis=-1)  # (B, 1, d_inner)
    win = jnp.concatenate([state.conv, x.astype(state.conv.dtype)], axis=1)  # (B, K, C)
    # win rows are [x_{t-K+1} .. x_t]; tap d multiplies x_{t-d} => flip taps.
    xc = jnp.einsum("bkc,kc->bc", win.astype(_F32),
                    jnp.flip(p["conv_w"], axis=0)) + p["conv_b"]
    xc = jax.nn.silu(xc)  # (B, C)
    proj = jnp.einsum("bc,cf->bf", xc, p["x_proj"]["w"].astype(_F32))
    dt_rank = proj.shape[-1] - 2 * N
    dt, B_, C_ = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,rd->bd", dt, p["dt_proj"]["w"].astype(_F32)) + p["dt_proj"]["b"])
    A = -jnp.exp(p["A_log"])
    h = jnp.exp(dt[..., None] * A) * state.ssm \
        + (dt * xc)[..., None] * B_[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, C_) + p["D"] * xc
    y = y * jax.nn.silu(z[:, 0].astype(_F32))
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"]["w"].astype(_F32))
    return out[:, None].astype(u.dtype), MambaState(conv=win[:, 1:], ssm=h)
