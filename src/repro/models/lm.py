"""Top-level language model: embedding → scanned layer stacks → head.

One class serves all 11 architectures.  Stacks come from
``ModelConfig.stacks()``; each Stack is lowered as one ``jax.lax.scan`` over
its repeat axis (params stacked on a leading dim), keeping HLO size
O(pattern length) regardless of depth.

Entry points (all pure functions of (params, inputs)):
  * ``loss(params, batch)``            — training objective (CE + MoE aux
                                          + optional deepseek-MTP head).
  * ``forward(params, batch)``         — hidden states (B, T, D).
  * ``prefill(params, batch, S_cap)``  — forward + per-layer decode caches.
  * ``decode_step(params, token, caches, ...)`` — one-token serve step.

LCSM ('hyena' family) configs delegate to models.hyena.HyenaLCSM: the
static path (train/prefill) is the FFT forward; decode runs through
repro.core.engine.FlashEngine (the paper's contribution) — see
repro/serving/lcsm_backend.py.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerDef, ModelConfig, Stack
from repro.models import attention as A
from repro.models import components as C
from repro.models import layers as L

_F32 = jnp.float32


def _stack_keys(key, n):
    return jax.random.split(key, n)


# Activation-sharding hook lives in components (shared with hyena/mamba).
from repro.models.components import activation_sharding, constrain as _constrain  # noqa: E402,F401


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_lcsm = cfg.family == "lcsm"
        if self.is_lcsm:
            from repro.models.hyena import HyenaLCSM

            self.lcsm = HyenaLCSM(cfg)
        else:
            self.stacks: tuple[Stack, ...] = cfg.stacks()

    # ------------------------------------------------------------------ init
    def init(self, key) -> Any:
        cfg = self.cfg
        if self.is_lcsm:
            return self.lcsm.init(key)
        ks = jax.random.split(key, 6 + len(self.stacks))
        params: dict[str, Any] = {
            "emb": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), _F32) * 0.02,
            "norm_f": L._init_norm(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unemb"] = jax.random.normal(
                ks[1], (cfg.vocab, cfg.d_model), _F32) * 0.02
        for si, stack in enumerate(self.stacks):
            def init_period(k, stack=stack):
                kk = jax.random.split(k, len(stack.pattern))
                return tuple(
                    L.init_layer(kk[j], cfg, ld)
                    for j, ld in enumerate(stack.pattern))
            params[f"stack{si}"] = jax.vmap(init_period)(
                _stack_keys(ks[2 + si], stack.repeat))
        if cfg.enc_layers:
            ke = jax.random.split(ks[-2], cfg.enc_layers)
            enc_ld = LayerDef("attn", "dense")
            params["enc"] = jax.vmap(
                lambda k: (L.init_layer(k, cfg, enc_ld),))(ke)
            params["enc_norm"] = L._init_norm(cfg, cfg.d_model)
        if cfg.mtp:
            params["mtp"] = {
                "layer": L.init_layer(ks[-1], cfg, LayerDef("attn", "dense")),
                "proj": C.init_dense(ks[-3], 2 * cfg.d_model, cfg.d_model),
                "norm": L._init_norm(cfg, cfg.d_model),
            }
        return params

    # --------------------------------------------------------------- encoder
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """Whisper encoder over precomputed mel-frame embeddings (stub
        frontend per the assignment). Bidirectional attention."""
        cfg = self.cfg
        freqs = A.rope_freqs(cfg.head_dim, cfg.rope_theta)

        def body(x, period):
            (p,) = period
            h = L._apply_norm(cfg, p["norm1"], x)
            B, T, _ = h.shape
            q = A._proj(p["attn"]["wq"], h, cfg.n_heads, cfg.head_dim)
            k = A._proj(p["attn"]["wk"], h, cfg.n_kv_heads, cfg.head_dim)
            v = A._proj(p["attn"]["wv"], h, cfg.n_kv_heads, cfg.head_dim)
            pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
            q, k = A.apply_rope(q, pos, freqs), A.apply_rope(k, pos, freqs)
            o = A._sdpa(q, k, v, None, cfg.n_kv_heads)  # no mask: bidirectional
            y = jnp.einsum("btf,fd->btd", o.reshape(B, T, -1),
                           p["attn"]["wo"]["w"], preferred_element_type=_F32)
            x = x + y.astype(x.dtype)
            h = L._apply_norm(cfg, p["norm2"], x)
            x = x + C.mlp_gelu(p["mlp"], h)
            return x, None

        x, _ = jax.lax.scan(body, frames, params["enc"])
        return L._apply_norm(cfg, params["enc_norm"], x)

    # --------------------------------------------------------------- forward
    def _embed(self, params, batch) -> jnp.ndarray:
        x = params["emb"][batch["tokens"]]  # (B, T, D)
        if "vis_embed" in batch:  # VLM stub frontend: prepend patch embeds
            x = jnp.concatenate([batch["vis_embed"].astype(x.dtype), x], axis=1)
        return x

    def _aux_in(self, params, batch, *, window=None) -> dict:
        aux: dict = {"window": window}
        if self.cfg.m_rope:
            if "pos3" in batch:
                aux["pos3"] = batch["pos3"]
            else:
                B, T = batch["tokens"].shape
                T += batch["vis_embed"].shape[1] if "vis_embed" in batch else 0
                aux["pos3"] = jnp.broadcast_to(jnp.arange(T)[None, None], (3, B, T))
        if self.cfg.enc_layers:
            aux["enc_out"] = self.encode(params, batch["enc_frames"])
        return aux

    def forward(self, params, batch, *, window=None, remat: bool = False):
        """Returns (hidden (B, T, D), moe_aux scalar).

        ``remat=True`` (the training path) checkpoints each scan period:
        only the (B, T, D) layer boundaries survive to the backward pass;
        attention/MoE internals are recomputed — the standard memory/compute
        trade that makes 4k×256 training fit HBM.
        """
        cfg = self.cfg
        if self.is_lcsm:
            from repro.models.hyena import hyena_forward

            e = params["emb"][batch["tokens"]]
            h = hyena_forward(params["ops"], e, pos_dim=cfg.filter_pos_dim,
                              remat=remat)
            return C.rms_norm(h, params["norm_f"]), jnp.zeros((), _F32)
        x = _constrain(self._embed(params, batch))
        aux_in = self._aux_in(params, batch, window=window)
        aux = jnp.zeros((), _F32)
        for si, stack in enumerate(self.stacks):
            def body(carry, period, stack=stack):
                x, aux = carry
                for j, ld in enumerate(stack.pattern):
                    def one_layer(p_, x_, ld=ld):
                        return L.apply_layer_train(p_, self.cfg, ld, x_, aux_in)
                    if remat and len(stack.pattern) > 1:
                        # nested per-layer remat: a hybrid (Jamba) period is
                        # 8 layers — without this the backward holds all 8
                        # layers' recompute working set at once.
                        one_layer = jax.checkpoint(
                            one_layer,
                            policy=jax.checkpoint_policies.nothing_saveable)
                    x, a = one_layer(period[j], x)
                    x = _constrain(x)
                    aux = aux + a
                return (x, aux), None
            if remat:
                body = jax.checkpoint(body,
                                      policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux), _ = jax.lax.scan(body, (x, aux), params[f"stack{si}"])
        return L._apply_norm(cfg, params["norm_f"], x), aux

    def logits(self, params, hidden: jnp.ndarray) -> jnp.ndarray:
        w = params["emb"] if self.cfg.tie_embeddings or self.is_lcsm else params["unemb"]
        return jnp.einsum("...d,vd->...v", hidden, w, preferred_element_type=_F32)

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        if self.is_lcsm:
            from repro.models.hyena import hyena_forward

            e = params["emb"][batch["tokens"]]
            h = hyena_forward(params["ops"], e, pos_dim=cfg.filter_pos_dim,
                              remat=True)
            h = C.rms_norm(h, params["norm_f"])
            return _ce_from_hidden(params["emb"], h, batch["targets"])
        hidden, aux = self.forward(params, batch, remat=True)
        n_vis = batch["vis_embed"].shape[1] if "vis_embed" in batch else 0
        w = params["emb"] if cfg.tie_embeddings else params["unemb"]
        loss = _ce_from_hidden(w, hidden[:, n_vis:], batch["targets"]) + 0.01 * aux
        if cfg.mtp:
            # depth-1 MTP (deepseek-v3): predict t+2 from [h_t ; emb(x_{t+1})].
            h = hidden[:, n_vis:]
            emb_next = params["emb"][batch["targets"]]  # x_{t+1} = target_t
            z = C.dense(jnp.concatenate([h[:, :-1], emb_next[:, :-1].astype(h.dtype)], -1),
                        params["mtp"]["proj"]["w"])
            z, _ = L.apply_layer_train(params["mtp"]["layer"], cfg,
                                       LayerDef("attn", "dense"), z, {"window": None})
            z = L._apply_norm(cfg, params["mtp"]["norm"], z)
            loss = loss + 0.3 * _ce_from_hidden(w, z, batch["targets"][:, 1:])
        return loss

    # --------------------------------------------------------------- caches
    def init_caches(self, batch_size: int, S: int, *, dtype=jnp.bfloat16,
                    window: int | None = None, enc_S: int | None = None):
        cfg = self.cfg
        enc_S = enc_S if enc_S is not None else cfg.enc_positions
        caches = []
        for stack in self.stacks:
            period = tuple(
                L.init_layer_cache(cfg, ld, batch_size, S, dtype=dtype,
                                   enc_S=enc_S, window=window)
                for ld in stack.pattern)
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (stack.repeat,) + x.shape)
                if isinstance(x, jnp.ndarray) else x, period))
        return caches

    # -------------------------------------------------------------- prefill
    def prefill(self, params, batch, S_cap: int, *, window=None,
                cache_dtype=jnp.bfloat16):
        """Full-sequence forward + decode caches. Returns (last_logits, caches)."""
        cfg = self.cfg
        x = _constrain(self._embed(params, batch))
        aux_in = self._aux_in(params, batch, window=window)
        caches = []
        for si, stack in enumerate(self.stacks):
            def body(x, period, stack=stack):
                new_caches = []
                for j, ld in enumerate(stack.pattern):
                    h = L._apply_norm(cfg, period[j]["norm1"], x)
                    new_caches.append(L.prefill_layer_cache(
                        period[j], cfg, ld, h, S_cap, aux_in, dtype=cache_dtype))
                    x, _ = L.apply_layer_train(period[j], cfg, ld, x, aux_in)
                    x = _constrain(x)
                return x, tuple(new_caches)
            x, stack_caches = jax.lax.scan(body, x, params[f"stack{si}"])
            caches.append(stack_caches)
        h = L._apply_norm(cfg, params["norm_f"], x)
        return self.logits(params, h[:, -1]), caches

    # ---------------------------------------------------------- decode step
    def decode_step(self, params, token: jnp.ndarray, caches, *,
                    window: int | None = None, pos3=None, enc_out=None):
        """token: (B, 1) int32 → (logits (B, V), new caches). One serve step."""
        cfg = self.cfg
        x = params["emb"][token]  # (B, 1, D)
        aux_in = {"window": window, "pos3": pos3, "enc_out": enc_out}
        new_caches = []
        for si, stack in enumerate(self.stacks):
            def body(x, xs, stack=stack):
                period, cache_period = xs
                new_period = []
                for j, ld in enumerate(stack.pattern):
                    x, c = L.apply_layer_decode(
                        period[j], cfg, ld, x, cache_period[j], aux_in)
                    x = _constrain(x)
                    new_period.append(c)
                return x, tuple(new_period)
            x, nc = jax.lax.scan(body, x, (params[f"stack{si}"], caches[si]))
            new_caches.append(nc)
        h = L._apply_norm(cfg, params["norm_f"], x)
        return self.logits(params, h[:, -1]), new_caches


def _ce(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    lg = logits.astype(_F32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


def _ce_from_hidden(w: jnp.ndarray, hidden: jnp.ndarray, targets: jnp.ndarray,
                    chunk: int = 256) -> jnp.ndarray:
    """Cross entropy without materializing (B, T, V) logits: scan over T
    chunks, each chunk's logits live only inside a checkpointed body (the
    backward recomputes them).  At vocab 152k × T 4096 the full logits are
    ~40 GiB/chip f32 — the dominant train-memory term before this."""
    B, T, D = hidden.shape
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    nc = hidden.shape[1] // chunk
    hb = hidden.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    tb = targets.reshape(B, nc, chunk).transpose(1, 0, 2)
    mask = (jnp.arange(nc * chunk) < T).reshape(nc, chunk)

    @jax.checkpoint
    def body(acc, xs):
        h, t, mk = xs
        lg = jnp.einsum("bcd,vd->bcv", h, w, preferred_element_type=_F32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - picked) * mk[None]), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), _F32), (hb, tb, mask))
    return tot / (B * T)


# ----------------------------------------------------------------- builders
# Bounded (FC005): hashable configs are unbounded in principle (tests
# build many dataclasses.replace variants), so cap the memo.
@functools.lru_cache(maxsize=32)
def build(name_or_cfg) -> LM:
    from repro.configs.base import get_config

    cfg = get_config(name_or_cfg) if isinstance(name_or_cfg, str) else name_or_cfg
    return LM(cfg)
