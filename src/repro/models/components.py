"""Shared neural-net building blocks (pure JAX, functional, pytree params)."""

from __future__ import annotations

import contextlib as _contextlib
import math

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(_F32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight).astype(dt)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(_F32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * weight + bias).astype(dt)


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    y = jnp.einsum("...d,df->...f", x, w, preferred_element_type=_F32)
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


def init_dense(key, d_in: int, d_out: int, *, bias: bool = False, scale: float | None = None,
               dtype=_F32):
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    p = {"w": (jax.random.normal(key, (d_in, d_out), _F32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_dense(p, x):
    return dense(x, p["w"], p.get("b"))


def mlp_gelu(p, x):
    """Paper §5 synthetic block: MLP with hidden 2D and GELU."""
    h = jax.nn.gelu(apply_dense(p["fc1"], x))
    return apply_dense(p["fc2"], h)


def init_mlp_gelu(key, d: int, hidden: int, dtype=_F32):
    k1, k2 = jax.random.split(key)
    return {
        "fc1": init_dense(k1, d, hidden, bias=True, dtype=dtype),
        "fc2": init_dense(k2, hidden, d, bias=True, dtype=dtype),
    }


def swiglu(p, x):
    """SwiGLU feed-forward: w2( silu(w1 x) * w3 x )."""
    gate = jax.nn.silu(apply_dense(p["w1"], x))
    up = apply_dense(p["w3"], x)
    return apply_dense(p["w2"], gate * up)


def init_swiglu(key, d: int, d_ff: int, dtype=_F32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": init_dense(k1, d, d_ff, dtype=dtype),
        "w3": init_dense(k3, d, d_ff, dtype=dtype),
        "w2": init_dense(k2, d_ff, d, dtype=dtype),
    }


def causal_shortconv_from_window(win: jnp.ndarray, weights: jnp.ndarray,
                                 T: int) -> jnp.ndarray:
    """Depthwise causal FIR over a window buffer.

    win: (B, w + T, C) where index w+t corresponds to output position t.
    weights: (k, C) with k <= w + 1; tap d multiplies position t - d.
    Returns (B, T, C).
    """
    w = win.shape[1] - T
    k = weights.shape[0]
    out = jnp.zeros((win.shape[0], T, win.shape[2]), _F32)
    for d in range(k):
        seg = jax.lax.slice_in_dim(win, w - d, w - d + T, axis=1)
        out = out + seg.astype(_F32) * weights[d]
    return out.astype(win.dtype)


# --------------------------------------------------------------------------
# Activation-sharding constraint hook.  GSPMD sometimes drops the batch
# sharding of intermediates inside scanned/looped stacks; the launcher pins
# the batch axis explicitly via this context (CPU tests leave it unset).
_ACT_SPEC = None
_ACT_MESH = None


@_contextlib.contextmanager
def activation_sharding(spec, mesh=None):
    """spec: PartitionSpec whose FIRST entry is the batch mesh axis.
    mesh: optional — lets model code shard_map channel-separable ops
    (FFT convolutions) that XLA's SPMD partitioner would replicate."""
    global _ACT_SPEC, _ACT_MESH
    old, _ACT_SPEC = _ACT_SPEC, spec
    oldm, _ACT_MESH = _ACT_MESH, mesh
    try:
        yield
    finally:
        _ACT_SPEC = old
        _ACT_MESH = oldm


def sharding_ctx():
    """(batch_axis, mesh) or (None, None)."""
    if _ACT_SPEC is None:
        return None, None
    return (_ACT_SPEC[0] if len(_ACT_SPEC) else None), _ACT_MESH


def constrain(x):
    if _ACT_SPEC is None:
        return x
    from jax.sharding import PartitionSpec as P

    batch_ax = _ACT_SPEC[0] if len(_ACT_SPEC) else None
    return jax.lax.with_sharding_constraint(
        x, P(batch_ax, *([None] * (x.ndim - 1))))
