"""Gated-linear-attention language model — the paper's "and Beyond" mixer
(Katharopoulos et al., "Transformers are RNNs" style) served in production.

Layer l on input u (B, T, D):

    z = GLA(rms_norm(u))        # cont(y,i,j) = λ^{j-i}·(k_i⊗v_i), read = q·S
    y = u + out_proj(z)
    u' = y + mlp(norm2(y))

The mixer is P.1∧P.2 (core/generic.GatedLinearAttention — the pre-mixer
RMS norm is folded INTO the mixer via its ``norm`` argument, so the
engine's activation buffers hold raw residual-stream values), which means
decode runs through the generic Flash-Inference engine
(core/generic.GenericFlashEngine): the fractal tile schedule with the
O((U+U2)·dk·dv) decayed-sum range algorithm, fused chunks, donated
buffers, continuous batching via serving/generic_backend.GenericServer.

Engine mapping (GenericModel protocol):
  a[0]    (B, Lbuf, D)  token embeddings
  s[l]    (B, Lbuf, dk, dv)  per-position mixer states
  a[l+1]  (B, Lbuf, D)  layer-l output (residual stream)

``decode_recurrent`` is the RNN-mode oracle (S_j = λS_{j-1} + k_j⊗v_j,
O(1) state per layer) that the differential and serving tests pin the
engine against — GLA happens to admit a compact recurrence; mixers that
don't are exactly why the generic schedule exists.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.generic import GatedLinearAttention
from repro.models import components as C

_F32 = jnp.float32


class GLALM:
    """GenericModel-protocol language model over GatedLinearAttention
    mixers.  Decode for ``cfg.family == "gla"`` runs through
    repro.core.generic.GenericFlashEngine with this model."""

    def __init__(self, cfg):
        assert cfg.family == "gla"
        self.cfg = cfg
        self.D = cfg.d_model
        self.dk = cfg.gla_dk or cfg.d_model
        self.dv = cfg.gla_dv or cfg.d_model
        self.lam = cfg.gla_lam
        self.n_levels = cfg.n_layers
        self.a0_width = self.D
        self.widths = (self.D,) * self.n_levels

    # params: {"emb": (V, D), "layers": [layer0..], "norm_f": (D,)}
    def init(self, key) -> Any:
        cfg = self.cfg
        ks = jax.random.split(key, self.n_levels + 1)

        def layer(k):
            kq, kk, kv, ko, km = jax.random.split(k, 5)
            return {
                "norm1": jnp.ones((self.D,), _F32),
                "wq": C.init_dense(kq, self.D, self.dk)["w"],
                "wk": C.init_dense(kk, self.D, self.dk)["w"],
                "wv": C.init_dense(kv, self.D, self.dv)["w"],
                "out_proj": C.init_dense(ko, self.dv, self.D),
                "norm2": jnp.ones((self.D,), _F32),
                "mlp": C.init_swiglu(km, self.D, cfg.d_ff),
            }
        return {
            "emb": jax.random.normal(ks[0], (cfg.vocab, self.D), _F32) * 0.02,
            "layers": [layer(ks[1 + i]) for i in range(self.n_levels)],
            "norm_f": jnp.ones((self.D,), _F32),
        }

    # ------------------------------------------------- GenericModel protocol
    def mixers(self, params) -> Sequence[GatedLinearAttention]:
        return tuple(
            GatedLinearAttention(wq=lp["wq"], wk=lp["wk"], wv=lp["wv"],
                                 lam=self.lam, norm=lp["norm1"])
            for lp in params["layers"])

    def block(self, params, level: int, z: jnp.ndarray,
              y: jnp.ndarray) -> jnp.ndarray:
        lp = params["layers"][level]
        h = y + C.dense(z.astype(y.dtype), lp["out_proj"]["w"])
        return h + C.swiglu(lp["mlp"], C.rms_norm(h, lp["norm2"]))

    def logits(self, params, z: jnp.ndarray) -> jnp.ndarray:
        h = C.rms_norm(z, params["norm_f"])
        return jnp.einsum("...d,vd->...v", h, params["emb"],
                          preferred_element_type=_F32)

    def advance(self, params, a_top: jnp.ndarray, rng):
        logits = self.logits(params, a_top)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return params["emb"][token], token

    # ---------------------------------------------------------- embeddings
    def embed_tokens(self, params, tokens: jnp.ndarray) -> jnp.ndarray:
        return params["emb"][tokens]  # (B, T, D)

    def embed_entry(self, params, e: jnp.ndarray) -> jnp.ndarray:
        return e  # a0 rows ARE embeddings (no fused projection streams)

    # ------------------------------------------------- recurrent oracle path
    def forward_tokens_recurrent(self, params, tokens: jnp.ndarray) -> jnp.ndarray:
        """(B, T) tokens -> (B, T, V) logits in RNN mode (mixer.recurrent):
        the teacher-forced full-sequence reference path."""
        u = params["emb"][tokens]
        for level, mix in enumerate(self.mixers(params)):
            z = mix.recurrent(u)
            u = self.block(params, level, z, u)
        return self.logits(params, u)

    def decode_recurrent(self, params, prompt, n_tokens: int) -> list[int]:
        """Greedy RNN-mode decode oracle: per-layer O(1) states stepped one
        token at a time — what the generic engine must reproduce."""
        mixers = self.mixers(params)
        S = [jnp.zeros((1, m.dk, m.dv), _F32) for m in mixers]

        def step(u):  # u (1, D) one position through all layers
            for l, mix in enumerate(mixers):
                S[l] = mix.step_state(S[l], u)
                z = mix.read(S[l], u)
                u = self.block(params, l, z[:, None], u[:, None])[:, 0]
            return u

        top = None
        for t in jnp.asarray(prompt, jnp.int32):
            top = step(params["emb"][t][None])
        out = []
        for _ in range(n_tokens):
            tok = int(jnp.argmax(self.logits(params, top)[0]))
            out.append(tok)
            top = step(params["emb"][tok][None])
        return out
