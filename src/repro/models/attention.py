"""Attention mixers: GQA/MHA with RoPE / M-RoPE / QKV-bias / sliding window,
and MLA (DeepSeek multi-head latent attention).

Functional style: ``init_*`` builds a params pytree, ``*_train`` runs the
full-sequence causal form, ``*_decode`` runs one step against a cache.
Shapes use (B, T, H, hd); GQA expands kv heads by repetition at contraction
time (no materialized repeat for the train path — einsum grouping).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.components import init_dense

_F32 = jnp.float32
_NEG = -1e9


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=_F32) / head_dim))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, freqs: jnp.ndarray) -> jnp.ndarray:
    """x: (B, T, H, hd); pos: (B, T) int32; freqs: (hd/2,)."""
    ang = pos[..., None].astype(_F32) * freqs  # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(_F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x: jnp.ndarray, pos3: jnp.ndarray, freqs: jnp.ndarray,
                 sections=(16, 24, 24)) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: head_dim/2 frequency slots split into (t, h, w)
    sections, each rotated by its own position stream.

    x: (B, T, H, hd); pos3: (3, B, T); sections sum to hd/2.
    """
    hd2 = x.shape[-1] // 2
    assert sum(sections) == hd2, (sections, hd2)
    ang_parts = []
    lo = 0
    for s, sec in enumerate(sections):
        ang_parts.append(pos3[s][..., None].astype(_F32) * freqs[lo : lo + sec])
        lo += sec
    ang = jnp.concatenate(ang_parts, axis=-1)  # (B, T, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(_F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- masking
def causal_mask(T: int, window: int | None = None) -> jnp.ndarray:
    """(T, T) additive mask; sliding window keeps [t-window+1, t]."""
    t = jnp.arange(T)
    m = t[None, :] <= t[:, None]
    if window is not None:
        m &= t[None, :] > t[:, None] - window
    return jnp.where(m, 0.0, _NEG).astype(_F32)


def _blocks(x, nc, chunk):
    """(B, S, Hkv, d) -> (nc, B, chunk, Hkv, d)."""
    B = x.shape[0]
    return x.reshape(B, nc, chunk, *x.shape[2:]).transpose(1, 0, 2, 3, 4)


def _block_mask(ci, chunk, S, T, causal, window):
    """(T, chunk) validity of kv block ci against end-aligned queries."""
    kpos = ci * chunk + jnp.arange(chunk)
    qpos = (S - T) + jnp.arange(T)
    valid = jnp.broadcast_to(kpos[None, :] < S, (T, chunk))
    if causal:
        valid = valid & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        valid = valid & (kpos[None, :] > qpos[:, None] - window)
    return valid


# Bounded (FC005): scale varies with head_dim and window with config, so
# an uncapped memo holds one compiled closure per attention configuration
# ever constructed; 32 covers any realistic process.
@functools.lru_cache(maxsize=32)
def _flash_fn(n_kv: int, causal: bool, window, chunk: int, scale):
    """Flash attention with a flash *backward*: the VJP re-runs the KV-block
    scan, recomputing each block's probabilities from (q, k, saved row
    logsumexp) — so neither pass ever materializes the (T, S) matrix.
    Plain jax.grad through the forward scan saves every block's logits
    (~O(T*S) again), which is exactly what sank the train_4k dry-run to
    96 GiB/chip of temp.
    """

    def fwd_scan(q, k, v):
        B, T, H, hd = q.shape
        v_hd = v.shape[-1]
        sc = (1.0 / math.sqrt(hd)) if scale is None else scale
        S = k.shape[1]
        G = H // n_kv
        nc = -(-S // chunk)
        if nc * chunk != S:
            k = jnp.pad(k, ((0, 0), (0, nc * chunk - S), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, nc * chunk - S), (0, 0), (0, 0)))
        qg = q.reshape(B, T, n_kv, G, hd)

        def body(carry, xs):
            m, l, acc = carry
            ci, kb, vb = xs
            lg = jnp.einsum("btkgh,bskh->bkgts", qg, kb,
                            preferred_element_type=_F32) * sc
            valid = _block_mask(ci, chunk, S, T, causal, window)
            lg = jnp.where(valid[None, None, None], lg, _NEG)
            m_new = jnp.maximum(m, lg.max(-1))
            p = jnp.exp(lg - m_new[..., None])
            resc = jnp.exp(m - m_new)
            l_new = l * resc + p.sum(-1)
            pv = jnp.einsum("bkgts,bskh->bkgth", p.astype(vb.dtype), vb,
                            preferred_element_type=_F32)
            return (m_new, l_new, pv + acc * resc[..., None]), None

        m0 = jnp.full((B, n_kv, G, T), _NEG, _F32)
        l0 = jnp.zeros((B, n_kv, G, T), _F32)
        a0 = jnp.zeros((B, n_kv, G, T, v_hd), _F32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (jnp.arange(nc), _blocks(k, nc, chunk),
                                 _blocks(v, nc, chunk)))
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None]
        lse = m + jnp.log(l)  # (B, K, G, T) row logsumexp
        return out, lse  # out: (B, K, G, T, v_hd)

    @jax.custom_vjp
    def flash(q, k, v):
        out, _ = fwd_scan(q, k, v)
        B, T, H, _ = q.shape
        return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, -1).astype(q.dtype)

    def flash_fwd(q, k, v):
        out, lse = fwd_scan(q, k, v)
        B, T, H, _ = q.shape
        o = out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, -1).astype(q.dtype)
        return o, (q, k, v, out, lse)

    def flash_bwd(res, g):
        q, k, v, out, lse = res
        B, T, H, hd = q.shape
        v_hd = v.shape[-1]
        sc = (1.0 / math.sqrt(hd)) if scale is None else scale
        S = k.shape[1]
        G = H // n_kv
        nc = -(-S // chunk)
        Sp = nc * chunk
        kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0))) if Sp != S else k
        vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0))) if Sp != S else v
        qg = q.reshape(B, T, n_kv, G, hd)
        go = g.reshape(B, T, n_kv, G, v_hd).transpose(0, 2, 3, 1, 4).astype(_F32)
        Dt = jnp.sum(go * out, axis=-1)  # (B, K, G, T) rowsum(dout*out)

        def body(dq, xs):
            ci, kb, vb = xs
            lg = jnp.einsum("btkgh,bskh->bkgts", qg, kb,
                            preferred_element_type=_F32) * sc
            valid = _block_mask(ci, chunk, S, T, causal, window)
            lg = jnp.where(valid[None, None, None], lg, _NEG)
            p = jnp.exp(lg - lse[..., None])  # zero where masked
            dv = jnp.einsum("bkgts,bkgtd->bskd", p.astype(go.dtype), go)
            dp = jnp.einsum("bkgtd,bskd->bkgts", go, vb.astype(_F32))
            ds = p * (dp - Dt[..., None]) * sc
            dq = dq + jnp.einsum("bkgts,bskh->btkgh", ds.astype(kb.dtype), kb,
                                 preferred_element_type=_F32)
            dk = jnp.einsum("bkgts,btkgh->bskh", ds.astype(qg.dtype), qg,
                            preferred_element_type=_F32)
            return dq, (dk, dv)

        dq0 = jnp.zeros((B, T, n_kv, G, hd), _F32)
        dq, (dks, dvs) = jax.lax.scan(
            body, dq0, (jnp.arange(nc), _blocks(kp, nc, chunk),
                        _blocks(vp, nc, chunk)))
        dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sp, n_kv, hd)[:, :S]
        dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, n_kv, v_hd)[:, :S]
        return (dq.reshape(B, T, H, hd).astype(q.dtype), dk.astype(k.dtype),
                dv.astype(v.dtype))

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def _sdpa_chunked(q, k, v, n_kv: int, *, causal: bool = True,
                  window: int | None = None, chunk: int = 1024,
                  scale: float | None = None):
    """Flash-style attention (see _flash_fn). q: (B,T,H,hd);
    k/v: (B,S,Hkv,.); queries end-aligned (query t at position S-T+t)."""
    return _flash_fn(n_kv, causal, window, chunk, scale)(q, k, v)


# Full-materialization is fine below this sequence length (and cheaper —
# no rescaling passes); above it the chunked path bounds memory.
_CHUNKED_MIN_T = 2048


# -------------------------------------------------------------------- GQA
class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S, Hkv, hd)
    v: jnp.ndarray  # (B, S, Hkv, hd)
    pos: jnp.ndarray  # (B,) int32 — per-slot valid length (continuous batching)


def init_gqa(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             *, qkv_bias: bool = False, dtype=_F32):
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_dense(ks[1], d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_dense(ks[2], d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_dense(ks[3], n_heads * head_dim, d_model, dtype=dtype),
    }


def _proj(p, x, H, hd):
    y = jnp.einsum("btd,df->btf", x, p["w"], preferred_element_type=_F32)
    if "b" in p:
        y = y + p["b"]
    B, T = x.shape[:2]
    return y.reshape(B, T, H, hd).astype(x.dtype)


def _sdpa(q, k, v, mask, n_kv: int):
    """q: (B,T,H,hd), k/v: (B,S,Hkv,hd), mask: (T,S) or (B,T,S) additive.

    k/v stay in their storage dtype (bf16 cache) — accumulation happens in
    f32 via preferred_element_type.  Upcasting the cache itself would
    materialize a 2× copy of the largest tensor in decode (and GSPMD then
    reshards the copy — the all-gather this comment is guarding against).
    """
    B, T, H, hd = q.shape
    G = H // n_kv
    qg = q.reshape(B, T, n_kv, G, hd)
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                        preferred_element_type=_F32)
    logits = logits / math.sqrt(hd)
    if mask is not None:
        mb = mask if mask.ndim == 3 else mask[None]
        logits = logits + mb[:, None, None]
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w.astype(v.dtype), v,
                     preferred_element_type=_F32)
    return out.reshape(B, T, H, hd).astype(q.dtype)


def gqa_train(p, x, *, n_heads, n_kv, head_dim, freqs, pos=None,
              window=None, m_rope_pos=None, m_rope_sections=None):
    """Full-sequence causal attention. x: (B, T, D) -> (B, T, D)."""
    B, T, _ = x.shape
    q = _proj(p["wq"], x, n_heads, head_dim)
    k = _proj(p["wk"], x, n_kv, head_dim)
    v = _proj(p["wv"], x, n_kv, head_dim)
    if m_rope_pos is not None:
        q = apply_m_rope(q, m_rope_pos, freqs, m_rope_sections)
        k = apply_m_rope(k, m_rope_pos, freqs, m_rope_sections)
    else:
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T)) if pos is None else pos
        q = apply_rope(q, pos, freqs)
        k = apply_rope(k, pos, freqs)
    if T >= _CHUNKED_MIN_T:
        out = _sdpa_chunked(q, k, v, n_kv, causal=True, window=window)
    else:
        out = _sdpa(q, k, v, causal_mask(T, window), n_kv)
    y = jnp.einsum("btf,fd->btd", out.reshape(B, T, -1), p["wo"]["w"],
                   preferred_element_type=_F32)
    return y.astype(x.dtype)


def gqa_decode(p, x, cache: KVCache, *, n_heads, n_kv, head_dim, freqs,
               window=None, m_rope_pos=None, m_rope_sections=None):
    """One-token step. x: (B, 1, D); cache ring-buffered when window is set.

    Returns (y (B,1,D), new cache).
    """
    B = x.shape[0]
    S = cache.k.shape[1]
    q = _proj(p["wq"], x, n_heads, head_dim)
    k = _proj(p["wk"], x, n_kv, head_dim)
    v = _proj(p["wv"], x, n_kv, head_dim)
    pos = cache.pos[:, None]  # (B, 1) per-slot positions
    if m_rope_pos is not None:
        q = apply_m_rope(q, m_rope_pos, freqs, m_rope_sections)
        k = apply_m_rope(k, m_rope_pos, freqs, m_rope_sections)
    else:
        q = apply_rope(q, pos, freqs)
        k = apply_rope(k, pos, freqs)
    # write slot (per row): plain append, or ring slot pos % S when windowed.
    if window is None:
        slot = jnp.minimum(cache.pos, S - 1)  # (B,)
    else:
        slot = cache.pos % S
    # select-based update (not scatter): elementwise over (B, S), so GSPMD
    # keeps it fully sharded along batch — no all-gather of the cache.
    hit = (jnp.arange(S)[None, :] == slot[:, None])[..., None, None]  # (B,S,1,1)
    kc = jnp.where(hit, k.astype(cache.k.dtype), cache.k)
    vc = jnp.where(hit, v.astype(cache.v.dtype), cache.v)
    # validity mask over cache slots, per row.
    idx = jnp.arange(S)[None, :]
    if window is None:
        valid = idx <= cache.pos[:, None]
    else:
        # ring buffer holds the last min(pos+1, S) positions.
        valid = idx < jnp.minimum(cache.pos + 1, S)[:, None]
    mask = jnp.where(valid, 0.0, _NEG).astype(_F32)[:, None, :]  # (B,1,S)
    out = _sdpa(q, kc, vc, mask, n_kv)
    y = jnp.einsum("btf,fd->btd", out.reshape(B, 1, -1), p["wo"]["w"],
                   preferred_element_type=_F32).astype(x.dtype)
    return y, KVCache(kc, vc, cache.pos + 1)


# ------------------------------------------------------------- cross-attn
def init_cross(key, d_model: int, n_heads: int, head_dim: int, dtype=_F32):
    return init_gqa(key, d_model, n_heads, n_heads, head_dim, dtype=dtype)


def cross_attention(p, x, enc_kv, *, n_heads, head_dim):
    """x: (B, T, D) queries; enc_kv: (B, S, D) encoder output (no mask)."""
    B, T, _ = x.shape
    q = _proj(p["wq"], x, n_heads, head_dim)
    k = _proj(p["wk"], enc_kv, n_heads, head_dim)
    v = _proj(p["wv"], enc_kv, n_heads, head_dim)
    out = _sdpa(q, k, v, None, n_heads)
    y = jnp.einsum("btf,fd->btd", out.reshape(B, T, -1), p["wo"]["w"],
                   preferred_element_type=_F32)
    return y.astype(x.dtype)


# -------------------------------------------------------------------- MLA
class MLACache(NamedTuple):
    ckv: jnp.ndarray    # (B, S, kv_lora) compressed latent
    k_rope: jnp.ndarray # (B, S, rope_dim) shared rotary key
    pos: jnp.ndarray    # (B,) int32 per-slot valid length


def init_mla(key, d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
             rope_dim: int, head_dim: int, v_head_dim: int | None = None,
             dtype=_F32):
    """DeepSeek-V2/V3 MLA. Decode caches only (kv_lora + rope_dim) per pos."""
    v_head_dim = head_dim if v_head_dim is None else v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": init_dense(ks[0], d_model, q_lora, dtype=dtype),
        "wq_b": init_dense(ks[1], q_lora, n_heads * (head_dim + rope_dim), dtype=dtype),
        "wkv_a": init_dense(ks[2], d_model, kv_lora + rope_dim, dtype=dtype),
        "wkv_b": init_dense(ks[3], kv_lora, n_heads * (head_dim + v_head_dim), dtype=dtype),
        "wo": init_dense(ks[4], n_heads * v_head_dim, d_model, dtype=dtype),
    }


def _mla_qkv(p, x, *, n_heads, head_dim, rope_dim, kv_lora, freqs, pos):
    B, T, _ = x.shape
    q = jnp.einsum("btd,df->btf", x, p["wq_a"]["w"], preferred_element_type=_F32)
    q = jnp.einsum("btf,fg->btg", q, p["wq_b"]["w"], preferred_element_type=_F32)
    q = q.reshape(B, T, n_heads, head_dim + rope_dim)
    q_nope, q_rope = q[..., :head_dim], q[..., head_dim:]
    q_rope = apply_rope(q_rope.astype(x.dtype), pos, freqs)
    kv = jnp.einsum("btd,df->btf", x, p["wkv_a"]["w"], preferred_element_type=_F32)
    ckv, k_rope = kv[..., :kv_lora], kv[..., kv_lora:]
    k_rope = apply_rope(k_rope[:, :, None].astype(x.dtype), pos, freqs)[:, :, 0]
    return q_nope.astype(x.dtype), q_rope, ckv.astype(x.dtype), k_rope


def _mla_attend(p, q_nope, q_rope, ckv, k_rope, mask, *, n_heads, head_dim,
                rope_dim, v_head_dim):
    """Latent-space attention: fold wkv_b's K-half into the query so scores
    contract against the compressed cache directly (decode-optimal form)."""
    B, T = q_nope.shape[:2]
    kv_lora = ckv.shape[-1]
    wkv_b = p["wkv_b"]["w"].reshape(kv_lora, n_heads, head_dim + v_head_dim)
    wk, wv = wkv_b[..., :head_dim], wkv_b[..., head_dim:]
    # absorb: q_lat[b,t,h,l] = q_nope . wk   (cache stays in storage dtype —
    # see _sdpa's note; accumulate f32 via preferred_element_type)
    q_lat = jnp.einsum("bthd,lhd->bthl", q_nope, wk, preferred_element_type=_F32)
    logits = jnp.einsum("bthl,bsl->bhts", q_lat.astype(ckv.dtype), ckv,
                        preferred_element_type=_F32)
    logits += jnp.einsum("bthr,bsr->bhts", q_rope, k_rope,
                         preferred_element_type=_F32)
    logits = logits / math.sqrt(head_dim + rope_dim)
    if mask is not None:
        logits = logits + (mask if mask.ndim == 3 else mask[None])[:, None]
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhts,bsl->bthl", w.astype(ckv.dtype), ckv,
                       preferred_element_type=_F32)
    out = jnp.einsum("bthl,lhd->bthd", o_lat, wv.astype(_F32))  # (B,T,H,vhd)
    y = jnp.einsum("btf,fd->btd", out.reshape(B, T, -1), p["wo"]["w"].astype(_F32))
    return y


def mla_train(p, x, *, n_heads, head_dim, rope_dim, kv_lora, v_head_dim, freqs):
    B, T, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    q_nope, q_rope, ckv, k_rope = _mla_qkv(
        p, x, n_heads=n_heads, head_dim=head_dim, rope_dim=rope_dim,
        kv_lora=kv_lora, freqs=freqs, pos=pos)
    if T >= _CHUNKED_MIN_T:
        # latent-space MLA == SDPA over 1 shared "key head" of dim
        # kv_lora+rope_dim with values = the latent cache itself.
        wkv_b = p["wkv_b"]["w"].reshape(kv_lora, n_heads, head_dim + v_head_dim)
        wk = wkv_b[..., :head_dim]
        q_lat = jnp.einsum("bthd,lhd->bthl", q_nope, wk,
                           preferred_element_type=_F32).astype(x.dtype)
        q_all = jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,T,H,l+r)
        k_all = jnp.concatenate([ckv, k_rope], axis=-1)[:, :, None]  # (B,S,1,l+r)
        o_lat = _sdpa_chunked(
            q_all, k_all, ckv[:, :, None], n_kv=1, causal=True,
            scale=1.0 / math.sqrt(head_dim + rope_dim))  # (B,T,H,l)
        wv = wkv_b[..., head_dim:]
        out = jnp.einsum("bthl,lhd->bthd", o_lat.astype(_F32), wv.astype(_F32))
        y = jnp.einsum("btf,fd->btd", out.reshape(B, T, -1),
                       p["wo"]["w"].astype(_F32))
        return y.astype(x.dtype)
    y = _mla_attend(p, q_nope, q_rope, ckv, k_rope, causal_mask(T),
                    n_heads=n_heads, head_dim=head_dim, rope_dim=rope_dim,
                    v_head_dim=v_head_dim)
    return y.astype(x.dtype)


def mla_decode(p, x, cache: MLACache, *, n_heads, head_dim, rope_dim,
               kv_lora, v_head_dim, freqs, window=None):
    B = x.shape[0]
    S = cache.ckv.shape[1]
    pos = cache.pos[:, None]  # (B, 1)
    q_nope, q_rope, ckv, k_rope = _mla_qkv(
        p, x, n_heads=n_heads, head_dim=head_dim, rope_dim=rope_dim,
        kv_lora=kv_lora, freqs=freqs, pos=pos)
    if window is None:
        slot = jnp.minimum(cache.pos, S - 1)
        valid = jnp.arange(S)[None, :] <= cache.pos[:, None]
    else:  # ring buffer over the last min(pos+1, S) positions
        slot = cache.pos % S
        valid = jnp.arange(S)[None, :] < jnp.minimum(cache.pos + 1, S)[:, None]
    hit = (jnp.arange(S)[None, :] == slot[:, None])[..., None]  # (B, S, 1)
    cc = jnp.where(hit, ckv.astype(cache.ckv.dtype), cache.ckv)
    kr = jnp.where(hit, k_rope.astype(cache.k_rope.dtype), cache.k_rope)
    mask = jnp.where(valid, 0.0, _NEG).astype(_F32)[:, None, :]
    y = _mla_attend(p, q_nope, q_rope, cc, kr, mask,
                    n_heads=n_heads, head_dim=head_dim, rope_dim=rope_dim,
                    v_head_dim=v_head_dim)
    return y.astype(x.dtype), MLACache(cc, kr, cache.pos + 1)
