"""Mixture-of-Experts feed-forward (top-k router, capacity-based dispatch,
optional shared experts, load-balance aux loss).

Dispatch is the GShard/Mixtral einsum form: a one-hot (token, expert,
capacity-slot) tensor routes tokens to per-expert buffers —

    buf[e, c, d]  = Σ_t dispatch[t, e, c] · x[t, d]        (all-to-all #1)
    out[t, d]     = Σ_{e,c} combine[t, e, c] · ffn(buf)[e, c, d]   (#2)

Under the production mesh (tokens→data, experts→model) GSPMD lowers these
two contractions to the canonical MoE all-to-alls, which is exactly the
communication pattern the roofline analysis must see.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.components import init_dense

_F32 = jnp.float32


def init_moe(key, d_model: int, d_ff: int, n_experts: int, *, top_k: int,
             n_shared: int = 0, shared_d_ff: int | None = None, dtype=_F32):
    import math

    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "router": init_dense(ks[0], d_model, n_experts, dtype=_F32),  # fp32 router
        # experts stacked on a leading axis -> shards experts→model.
        "w1": jax.random.normal(ks[1], (n_experts, d_model, d_ff), _F32) * scale,
        "w3": jax.random.normal(ks[2], (n_experts, d_model, d_ff), _F32) * scale,
        "w2": jax.random.normal(ks[3], (n_experts, d_ff, d_model), _F32) * (1.0 / math.sqrt(d_ff)),
    }
    p["w1"] = p["w1"].astype(dtype); p["w3"] = p["w3"].astype(dtype); p["w2"] = p["w2"].astype(dtype)
    if n_shared:
        sdf = d_ff if shared_d_ff is None else shared_d_ff
        from repro.models.components import init_swiglu
        p["shared"] = init_swiglu(ks[4], d_model, sdf * n_shared, dtype=dtype)
    return p


def moe_ffn(p, x, *, n_experts: int, top_k: int, capacity_factor: float = 1.25,
            min_capacity: int = 4, group_size: int = 512):
    """x: (B, T, D) -> (out (B, T, D), aux_loss scalar).

    GShard-style *grouped* dispatch: tokens are split into groups of
    ``group_size`` with per-group capacity ``Cg = g·k·f/E``, so the dispatch
    one-hot is (G, g, E, Cg) — total elements tokens·g·k·f, independent of E
    (the ungrouped form is tokens²·k·f/E and explodes at pod scale).  Groups
    shard over the data axis, experts over model; GSPMD turns the two
    dispatch/combine contractions into the canonical MoE all-to-alls.
    """
    B, T, D = x.shape
    n_tok = B * T
    g = min(group_size, n_tok)
    while n_tok % g:  # keep groups exact (n_tok is a power-of-two-ish batch)
        g //= 2
    G = n_tok // g
    xt = x.reshape(G, g, D)

    logits = jnp.einsum("Gtd,de->Gte", xt.astype(_F32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, E)

    # top-k gates, renormalized over the chosen experts.
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (G, g, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(min_capacity, int(capacity_factor * top_k * g / n_experts))
    capacity = min(capacity, g)

    # position of each (token, choice) in its expert's per-group queue;
    # priority: choice 0 of all tokens first, then choice 1, ...
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=_F32)  # (G, g, k, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, top_k * g, n_experts)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat  # (G, k*g, E)
    pos_in_e = pos_in_e.reshape(G, top_k, g, n_experts).transpose(0, 2, 1, 3)
    slot = jnp.einsum("Gtke,Gtke->Gtk", pos_in_e, onehot)  # (G, g, k)
    keep = slot < capacity
    gate_vals = gate_vals * keep  # dropped tokens pass through (residual adds x)

    slot_oh = jax.nn.one_hot(slot.astype(jnp.int32), capacity, dtype=_F32)
    disp = jnp.einsum("Gtke,Gtkc->Gtec", onehot * keep[..., None], slot_oh)
    comb = jnp.einsum("Gtk,Gtke,Gtkc->Gtec", gate_vals, onehot, slot_oh)

    buf = jnp.einsum("Gtec,Gtd->Gecd", disp, xt.astype(_F32))  # a2a #1
    h = jnp.einsum("Gecd,edf->Gecf", buf, p["w1"].astype(_F32))
    gt = jnp.einsum("Gecd,edf->Gecf", buf, p["w3"].astype(_F32))
    h = jax.nn.silu(h) * gt
    eout = jnp.einsum("Gecf,efd->Gecd", h, p["w2"].astype(_F32))
    out = jnp.einsum("Gtec,Gecd->Gtd", comb, eout)  # a2a #2

    if "shared" in p:
        from repro.models.components import swiglu
        out = out + swiglu(p["shared"], xt.astype(_F32))

    # load-balance aux (Switch): E * Σ_e f_e · P_e, averaged over groups.
    me = probs.mean(1)  # (G, E)
    ce = onehot.sum(2).mean(1) / top_k  # fraction routed per expert, (G, E)
    aux = n_experts * jnp.mean(jnp.sum(me * ce, axis=-1))
    return out.reshape(B, T, D).astype(x.dtype), aux
