"""Hyena operators (Poli et al. 2023) — the paper's LCSM case study.

Order-3 operator on input u (B, T, D):

    (v, x1, x2) = split(in_proj(norm1(u)), 3)       # width 3D
    v, x1, x2   = shortconv(v), shortconv(x1), shortconv(x2)
    v1 = x1 ⊙ (rho1 * v)          # long conv 1   — engine level 2k
    v2 = x2 ⊙ (rho2 * v1)         # long conv 2   — engine level 2k+1
    y  = u + out_proj(v2)
    u' = y + mlp(norm2(y))

Filters are implicit (positional-feature MLP × learned per-channel
exponential-decay window) and data-independent → Algorithm 2's rectangle
tiling applies.

Two equivalent execution paths (tests assert they agree):
  * ``hyena_forward``  — static full-sequence form (training / prefill):
    FFT long convs (tau.conv_causal_fft) + Pallas short convs.
  * ``HyenaLCSM``      — FlashEngine-compatible decode (LCSMModel protocol).
    The v-stream short conv is *folded into the long filter* (causal LTI
    composition: shortconv then rho  ==  (rho ∗ w_short) as one filter), so
    each operator maps to exactly 2 engine mixer levels; gate-stream short
    convs run in-block from the activation window.

Engine activation layout (D = d_model):
  a[2k]   width 4D: (v_raw, x1_raw, x2_raw, u)   — operator-k inputs
  a[2k+1] width 3D: (v1, x2_raw, u)
  a[2k+2] width 4D (next op) or D (final u' of the last operator).
"""

from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import tau as tau_mod
from repro.core.engine import LevelSpec
from repro.kernels import ops as kops
from repro.models import components as C

_F32 = jnp.float32


# ---------------------------------------------------------- implicit filter
def positional_features(length: int, dim: int) -> jnp.ndarray:
    """(length, dim): normalized time + sin/cos harmonics."""
    t = jnp.arange(length, dtype=_F32) / max(length, 1)
    feats = [t]
    k = 1
    while len(feats) < dim:
        feats.append(jnp.sin(2 * math.pi * k * t))
        if len(feats) < dim:
            feats.append(jnp.cos(2 * math.pi * k * t))
        k += 1
    return jnp.stack(feats, axis=-1)  # (length, dim)


def init_filter(key, d_model: int, *, pos_dim: int, width: int,
                decay_fast: float, decay_slow: float, n_filters: int = 2,
                groups: int = 0):
    """groups > 0: multi-head Hyena (Massaroli et al.) — one implicit filter
    per group of D/groups channels instead of per channel."""
    ch = groups if groups else d_model
    ks = jax.random.split(key, 4)
    lo, hi = math.log(decay_slow), math.log(decay_fast)
    alphas = jnp.exp(
        lo + (hi - lo) * jax.random.uniform(ks[3], (n_filters, ch), _F32))
    return {
        "fc1": C.init_dense(ks[0], pos_dim, width, bias=True),
        "fc2": C.init_dense(ks[1], width, width, bias=True),
        "fc3": C.init_dense(ks[2], width, n_filters * ch, bias=True),
        "alphas": alphas,  # (n_filters, ch) decay rates
    }


def materialize_filters(p, length: int, d_model: int, *, pos_dim: int):
    """Returns (n_filters, length, D) data-independent filters.  With
    grouped (multi-head) filters, each group's filter is broadcast across
    its D/groups channels."""
    feats = positional_features(length, pos_dim)
    h = jnp.sin(C.apply_dense(p["fc1"], feats))
    h = jnp.sin(C.apply_dense(p["fc2"], h))
    h = C.apply_dense(p["fc3"], h)  # (length, n_filters*ch)
    nf, ch = p["alphas"].shape
    h = h.reshape(length, nf, ch).transpose(1, 0, 2)  # (nf, L, ch)
    t = jnp.arange(length, dtype=_F32)[None, :, None]
    window = jnp.exp(-p["alphas"][:, None, :] * t)
    rho = h * window / math.sqrt(length)
    if ch != d_model:  # shared filters: repeat per group
        rho = jnp.repeat(rho, d_model // ch, axis=-1)
    return rho


def compose_filters(rho: jnp.ndarray, taps: jnp.ndarray) -> jnp.ndarray:
    """(rho ∗ taps) truncated to len(rho): fold a K-tap causal FIR into a
    long filter (exact — both are causal LTI)."""
    L = rho.shape[0]
    out = jnp.zeros_like(rho)
    for d in range(taps.shape[0]):
        out = out.at[d:].add(rho[: L - d] * taps[d])
    return out


# ------------------------------------------------------------------ params
def init_hyena_operator(key, d_model: int, d_ff: int, cfg) -> dict:
    ks = jax.random.split(key, 6)
    K = cfg.short_conv_k
    return {
        "norm1": jnp.ones((d_model,), _F32),
        "in_proj": C.init_dense(ks[0], d_model, 3 * d_model),
        "short_w": (jax.random.normal(ks[1], (K, 3 * d_model), _F32) / K),
        "filter": init_filter(
            ks[2], d_model, pos_dim=cfg.filter_pos_dim,
            width=cfg.filter_mlp_width, decay_fast=cfg.filter_decay_fast,
            decay_slow=cfg.filter_decay_slow,
            groups=cfg.hyena_filter_groups),
        "out_proj": C.init_dense(ks[3], d_model, d_model),
        "norm2": jnp.ones((d_model,), _F32),
        "mlp": C.init_swiglu(ks[4], d_model, d_ff),
    }


# ------------------------------------------------------- static (train) path
def _fftconv(y: jnp.ndarray, rho: jnp.ndarray) -> jnp.ndarray:
    """Causal FFT conv, shard_map'd per (batch, channel) shard when a mesh
    context is active — XLA's SPMD partitioner has no FFT partitioning rule
    and replicates the operands otherwise (measured 12 GiB c64 temps per
    conv at hyena train scale).  τ is channel-separable so the local form
    is exact."""
    dp, mesh = C.sharding_ctx()
    if mesh is None:
        return tau_mod.conv_causal_fft(y, rho[None])
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    ch = None if "model" in dp_axes else "model"  # pure-DP: channels local
    spec = P(dp, None, ch)
    return shard_map(lambda yl, rl: tau_mod.conv_causal_fft(yl, rl[None]),
                     mesh=mesh, in_specs=(spec, P(None, ch)),
                     out_specs=spec, check_rep=False)(y, rho)


def hyena_operator_forward(p, u: jnp.ndarray, *, pos_dim: int) -> jnp.ndarray:
    """One operator, full sequence. u: (B, T, D)."""
    B, T, D = u.shape
    z = C.dense(C.rms_norm(u, p["norm1"]), p["in_proj"]["w"])  # (B, T, 3D)
    z = kops.short_conv(z, p["short_w"])
    v, x1, x2 = jnp.split(z, 3, axis=-1)
    rho = materialize_filters(p["filter"], T, D, pos_dim=pos_dim)  # (2, T, D)
    v1 = x1 * _fftconv(v.astype(_F32), rho[0]).astype(u.dtype)
    v2 = x2 * _fftconv(v1.astype(_F32), rho[1]).astype(u.dtype)
    y = u + C.dense(v2, p["out_proj"]["w"])
    return y + C.swiglu(p["mlp"], C.rms_norm(y, p["norm2"]))


def hyena_forward(params: Sequence[dict], u: jnp.ndarray, *, pos_dim: int,
                  remat: bool = False) -> jnp.ndarray:
    if remat:
        # close over pos_dim: jax.checkpoint traces keyword args.
        op = jax.checkpoint(
            lambda p, u: hyena_operator_forward(p, u, pos_dim=pos_dim),
            policy=jax.checkpoint_policies.nothing_saveable)
    else:
        op = lambda p, u: hyena_operator_forward(p, u, pos_dim=pos_dim)  # noqa: E731
    for p in params:
        u = C.constrain(op(p, u))
    return u


# ------------------------------------------------- FlashEngine-compatible
class HyenaLCSM:
    """LCSMModel-protocol wrapper: n_ops operators -> 2·n_ops mixer levels.

    Decode for the 'hyena' arch and all '*-hyena' twins runs through
    repro.core.engine.FlashEngine with this model.
    """

    def __init__(self, cfg):
        self.cfg = cfg
        self.D = cfg.d_model
        self.n_ops = cfg.n_layers // (cfg.hyena_order - 1)
        self.ctx_window = cfg.short_conv_k - 1
        self.a0_width = 4 * self.D
        levels = []
        for k in range(self.n_ops):
            last = k == self.n_ops - 1
            levels.append(LevelSpec(width=3 * self.D, conv_start=0, conv_size=self.D))
            levels.append(LevelSpec(width=(self.D if last else 4 * self.D),
                                    conv_start=0, conv_size=self.D))
        self.levels = tuple(levels)

    # params: {"emb": (V, D), "ops": [op0..], "norm_f": (D,), "head": {...}}
    def init(self, key) -> Any:
        cfg = self.cfg
        ks = jax.random.split(key, self.n_ops + 2)
        return {
            "emb": jax.random.normal(ks[0], (cfg.vocab, self.D), _F32) * 0.02,
            "ops": [init_hyena_operator(ks[1 + k], self.D, cfg.d_ff, cfg)
                    for k in range(self.n_ops)],
            "norm_f": jnp.ones((self.D,), _F32),
        }

    # ---------------------------------------------------------- embeddings
    def embed_entry(self, params, e: jnp.ndarray) -> jnp.ndarray:
        """Token embedding e (B, D) -> a0 row (B, 4D): raw operator-0 streams."""
        z = C.dense(C.rms_norm(e, params["ops"][0]["norm1"]),
                    params["ops"][0]["in_proj"]["w"])  # (B, 3D)
        return jnp.concatenate([z, e], axis=-1)

    def embed_tokens(self, params, tokens: jnp.ndarray) -> jnp.ndarray:
        e = params["emb"][tokens]  # (B, T, D)
        z = C.dense(C.rms_norm(e, params["ops"][0]["norm1"]),
                    params["ops"][0]["in_proj"]["w"])
        return jnp.concatenate([z, e], axis=-1)  # (B, T, 4D)

    # -------------------------------------------------------------- filters
    def filters(self, params, length: int):
        out = []
        for k in range(self.n_ops):
            op = params["ops"][k]
            rho = materialize_filters(op["filter"], length, self.D,
                                      pos_dim=self.cfg.filter_pos_dim)
            w_v = op["short_w"][:, : self.D]  # v-stream taps
            out.append(compose_filters(rho[0], w_v))  # level 2k
            out.append(rho[1])                        # level 2k+1
        return out

    # ---------------------------------------------------------------- block
    def block(self, params, level: int, b: jnp.ndarray,
              acts: Sequence[jnp.ndarray]) -> jnp.ndarray:
        D = self.D
        T = b.shape[1]
        k, phase = divmod(level, 2)
        op = params["ops"][k]
        win = acts[level]  # (B, w+T, width of a[level])
        if phase == 0:
            # gate with shortconv(x1); pass x2_raw and u through.
            x1 = C.causal_shortconv_from_window(
                win[:, :, D : 2 * D], op["short_w"][:, D : 2 * D], T)
            v1 = x1 * b
            rest = win[:, -T:, 2 * D : 4 * D]  # (x2_raw, u)
            return jnp.concatenate([v1, rest], axis=-1)
        # phase 1: finish the operator.
        x2 = C.causal_shortconv_from_window(
            win[:, :, D : 2 * D], op["short_w"][:, 2 * D : 3 * D], T)
        u = win[:, -T:, 2 * D : 3 * D]
        y = u + C.dense(x2 * b, op["out_proj"]["w"])
        z = y + C.swiglu(op["mlp"], C.rms_norm(y, op["norm2"]))
        if k == self.n_ops - 1:
            return z
        nxt = params["ops"][k + 1]
        zp = C.dense(C.rms_norm(z, nxt["norm1"]), nxt["in_proj"]["w"])
        return jnp.concatenate([zp, z], axis=-1)

    # -------------------------------------------------------------- advance
    def logits(self, params, z: jnp.ndarray) -> jnp.ndarray:
        h = C.rms_norm(z, params["norm_f"])
        return jnp.einsum("...d,vd->...v", h, params["emb"],
                          preferred_element_type=_F32)

    def advance(self, params, acts: Sequence[jnp.ndarray], rng):
        z = acts[2 * self.n_ops][:, -1]  # (B, D) — final activation
        logits = self.logits(params, z)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        e = params["emb"][token]
        return self.embed_entry(params, e), token

    # ------------------------------------------------- static reference path
    def forward_tokens(self, params, tokens: jnp.ndarray,
                       remat: bool = False) -> jnp.ndarray:
        """(B, T) tokens -> (B, T, V) logits, static path (train/prefill)."""
        e = params["emb"][tokens]
        z = hyena_forward(params["ops"], e, pos_dim=self.cfg.filter_pos_dim,
                          remat=remat)
        return self.logits(params, z)
