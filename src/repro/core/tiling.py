"""Fractal tile schedule for relaxed (online) convolution — paper §3.1.

The contribution space of an online convolution is the lower triangle
``{(i, t) : 1 <= i <= t <= L}`` where cell ``(i, t)`` is the contribution of
input ``y_i`` to output ``z_t``.  Flash Inference covers this triangle with

  * L "red cells"  — the diagonal ``(i, i)`` (the ``y_i * rho_0`` term), and
  * "gray tiles"   — at step ``i`` (1-based), a square tile of side
    ``U = 2^nu(i)`` (largest power of two dividing ``i``) covering the
    contributions of ``y[i-U+1 .. i]`` to ``z[i+1 .. i+U]``.

Every off-diagonal cell is covered exactly once and causality is respected:
a tile at step ``i`` only reads inputs with index <= i (all available once
``z_{i-1}`` has been returned) and only writes outputs with index > i.

Everything in this module is plain Python/NumPy; it is schedule metadata, not
traced computation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator


def largest_pow2_divisor(i: int) -> int:
    """``2^nu(i)``: the side of the gray tile unlocked at step ``i`` (>=1)."""
    if i <= 0:
        raise ValueError(f"step index must be positive, got {i}")
    return i & (-i)


@dataclass(frozen=True)
class Tile:
    """Gray tile unlocked at step ``i``: inputs [in_lo, in_hi] -> outputs [out_lo, out_hi].

    All indices are 1-based and inclusive, matching the paper's notation.
    ``out_side <= side`` only when L is not a power of two (the tile's output
    range is clipped at L; its input range never is, so coverage is kept).
    """

    step: int
    side: int
    out_side: int

    @property
    def in_lo(self) -> int:
        return self.step - self.side + 1

    @property
    def in_hi(self) -> int:
        return self.step

    @property
    def out_lo(self) -> int:
        return self.step + 1

    @property
    def out_hi(self) -> int:
        return self.step + self.out_side


def tile_schedule(L: int) -> Iterator[Tile]:
    """Yield the gray tiles for generating ``L`` tokens, in execution order.

    The paper assumes ``L = 2^P`` (then all tiles are squares that fit
    exactly); for other L we clip each tile's *output* range at L, which
    preserves exact single coverage of every existing contribution cell.
    """
    for i in range(1, L):
        side = largest_pow2_divisor(i)
        yield Tile(step=i, side=side, out_side=min(side, L - i))


def schedule_segment(
    start_step: int,
    K: int,
    *,
    origin: int = 0,
    horizon: int | None = None,
    last_step: int | None = None,
) -> tuple[int, ...]:
    """Tile sides unlocked at relative steps ``start_step .. start_step+K-1``.

    The segment is the trace-time metadata a fused ``decode_chunk`` needs: one
    entry per red step, ``2^nu(step)`` where a gray tile runs and ``0`` where
    the per-step schedule would skip it —

      * ``horizon`` (= Lbuf): the tile at step ``r`` writes outputs starting at
        absolute position ``origin + r``; if even the first one falls outside
        the buffer the whole tile is a no-op and the per-step driver skips it
        (partially spilling tiles still run and are clipped inside the tile).
      * ``last_step``: the overall schedule length — no tile runs after the
        final red step (its outputs would never be read).

    Segments double as jit-cache keys: for K a power of two and chunks aligned
    to the schedule (``start_step = j*K + 1``), ``nu(j*K + i) = nu(i)`` for
    ``0 < i < K``, so every interior entry is chunk-invariant and only the last
    entry (and horizon/tail clipping) varies — the number of distinct segments
    over a whole generation is O(log L), not O(L/K).
    """
    if start_step < 1:
        raise ValueError(f"start_step must be positive, got {start_step}")
    if K < 1:
        raise ValueError(f"segment length must be positive, got {K}")
    seg = []
    for r in range(start_step, start_step + K):
        side = largest_pow2_divisor(r)
        if last_step is not None and r >= last_step:
            side = 0  # no tile after the final red step
        if horizon is not None and origin + r >= horizon:
            side = 0  # first output position already past the buffer
        seg.append(side)
    return tuple(seg)


def tile_histogram(L: int) -> dict[int, int]:
    """Map tile side -> number of tiles (Proposition 1: 2^(P-1-q) tiles of side 2^q)."""
    hist: dict[int, int] = {}
    for t in tile_schedule(L):
        hist[t.side] = hist.get(t.side, 0) + 1
    return hist


def activation_positions_touched(L: int) -> int:  # noqa: F811 (canonical def)
    """Total activation positions read+written by all tau calls (paper §3.3):
    O(L log L), vs Omega(L^2) for lazy/eager."""
    return sum(t.side + t.out_side for t in tile_schedule(L))


def validate_tiling(L: int) -> None:
    """Assert the schedule covers each off-diagonal contribution exactly once,
    causally.  Raises AssertionError otherwise.  O(L^2) — test-sized L only.
    """
    covered = {}
    for t in tile_schedule(L):
        assert t.in_hi < t.out_lo, f"tile {t} is not causal (r >= l')"
        assert t.in_lo >= 1 and t.out_hi <= L, f"tile {t} out of range"
        for i in range(t.in_lo, t.in_hi + 1):
            for z in range(t.out_lo, t.out_hi + 1):
                key = (i, z)
                assert key not in covered, f"cell {key} covered twice: {covered[key]} and {t}"
                covered[key] = t
    # Red cells cover the diagonal; everything else must be covered by a tile.
    for z in range(1, L + 1):
        for i in range(1, z):
            assert (i, z) in covered, f"cell ({i},{z}) never covered"
    # Causal completeness: the tile contributing (i, z) must run at a step < z,
    # i.e. by the time z is returned all its contributions are in.
    for (i, z), t in covered.items():
        assert t.step < z, f"cell ({i},{z}) accounted too late by {t}"


def theoretical_tau_flops(L: int, d: int = 1, impl: str = "fft") -> float:
    """Theorem 2 cost model: sum over q of 2^(P-1-q) * T(2^q, 2^q).

    ``fft``    : T(U, U) = d * 2U * log2(2U) * C   (order-2U FFT, App. C)
    ``direct`` : T(U, U) = d * U^2
    Returned in units of multiply-adds (the constant C for FFT is taken as 5,
    the usual split-radix estimate, times 2 transforms + pointwise per App. C).
    """
    P = int(math.log2(L))
    assert 1 << P == L, "cost model assumes L = 2^P"
    total = 0.0
    for q in range(P):
        U = 1 << q
        n_tiles = 1 << (P - 1 - q)
        if impl == "fft":
            n = 2 * U
            # 2 DFTs (input fwd + inverse; filter DFT precomputed, App. C)
            # + pointwise complex multiply.
            per_tile = d * (2 * 5 * n * math.log2(n) + 6 * n)
        elif impl == "direct":
            per_tile = d * U * U * 2
        else:
            raise ValueError(impl)
        total += n_tiles * per_tile
    return total


def naive_flops(L: int, d: int = 1) -> float:
    """Lazy/eager baseline cost: Omega(L^2) multiply-adds."""
    return d * L * (L - 1)  # sum_t 2*(t-1)


