"""The generic Flash Inference framework — paper §4 / Algorithm 4.

Any mixer that is

  P.1 contribution-based:  mixer(y)_j = read(agg(cont(y,1,j) … cont(y,j,j)))
      with ASSOCIATIVE agg over an intermediate state space X, and
  P.2 query-independent:   cont(y,i,·) depends only on y_{1..i},

admits the fractal tile schedule with a black-box range algorithm

  A(y, [l,r], [l',r'])_p = agg(cont(y,l,p), …, cont(y,r,p))   (r < l').

``GenericFlashEngine`` drives Algorithm 4 for any ``GenericMixer``;
``GatedLinearAttention`` instantiates it for a non-convolution member of
the class (the paper's "and Beyond"): cont(y,i,j) = λ^{j-i}·(k_i ⊗ v_i),
agg = +, read_j(S) = q_j·S — with an O((L1+L2)·d_k·d_v) range algorithm
exploiting the geometric decay (vs the naive L1·L2·d_k·d_v).
"""

from __future__ import annotations

from typing import Any, Protocol

import jax
import jax.numpy as jnp

from repro.core.tiling import largest_pow2_divisor

_F32 = jnp.float32


class GenericMixer(Protocol):
    """P.1 ∧ P.2 mixer over inputs y (B, L, D_in)."""

    def init_state(self, batch: int, length: int) -> Any:
        """Zero (agg-neutral) state buffer b: pytree with leading (B, L)."""

    def cont_diag(self, y_i: jnp.ndarray, i) -> Any:
        """cont(y, i, i): contribution of position i to itself (X-valued,
        leading dim B)."""

    def range_alg(self, y_seg: jnp.ndarray, in_lo, out_offsets: jnp.ndarray) -> Any:
        """A(y, [in_lo, in_lo+U), outputs at in_lo+U-1+out_offsets):
        y_seg (B, U, D_in); out_offsets (U2,) 1-based distances past the
        last input.  Returns X-valued (B, U2, ...)."""

    def agg(self, b: Any, x: Any) -> Any:
        """Associative aggregation (elementwise over leading dims)."""

    def read(self, b_i: Any, y_i: jnp.ndarray) -> jnp.ndarray:
        """Map state at a finalized position to the mixer output (B, D_out).
        y_i is the position's own input (available at read time — P.2 only
        constrains *contributions*, not the read)."""


class GenericFlashEngine:
    """Algorithm 4: autoregressive evaluation of a GenericMixer with
    L-1 calls to A (2^(P-1-q) of length 2^q each) + L diagonal conts."""

    def __init__(self, mixer: GenericMixer, batch: int, length: int):
        self.mixer = mixer
        self.B = batch
        self.L = length

    def run(self, next_input, y0: jnp.ndarray):
        """next_input(outputs_so_far list, z_i (B, D_out)) -> y_{i+1} (B, D_in).
        Returns (ys (B, L, D_in), zs (B, L, D_out)) with z produced strictly
        causally (z_i read before y_{i+1} is requested)."""
        m = self.mixer
        b = m.init_state(self.B, self.L)
        ys = [y0]
        zs = []
        for i in range(1, self.L + 1):  # 1-based positions
            y_i = ys[-1]
            # red cell: finalize b_i
            bi = jax.tree.map(lambda leaf: leaf[:, i - 1], b)
            bi = m.agg(bi, m.cont_diag(y_i, i))
            b = jax.tree.map(
                lambda leaf, x: leaf.at[:, i - 1].set(x), b, bi)
            z_i = m.read(bi, y_i)
            zs.append(z_i)
            if i < self.L:
                # gray tile: inputs [i-U+1, i] -> outputs [i+1, i+U]
                U = largest_pow2_divisor(i)
                U_out = min(U, self.L - i)
                y_seg = jnp.stack(ys[i - U:], axis=1)  # (B, U, D_in)
                offs = jnp.arange(1, U_out + 1)
                contrib = m.range_alg(y_seg, i - U + 1, offs)
                seg = jax.tree.map(lambda leaf: leaf[:, i : i + U_out], b)
                seg = m.agg(seg, contrib)
                b = jax.tree.map(
                    lambda leaf, x: jax.lax.dynamic_update_slice_in_dim(
                        leaf, x, i, axis=1), b, seg)
                ys.append(next_input(zs, z_i))
        return jnp.stack(ys, axis=1), jnp.stack(zs, axis=1)


# ------------------------------------------------------- "and Beyond" (§6)
class GatedLinearAttention:
    """Gated linear attention as a P.1∧P.2 mixer.

    cont(y, i, j) = λ^(j-i) · (k_i ⊗ v_i)   ∈ X = R^{dk×dv}
    agg = +,   read_j(S) = normalize(q_j)ᵀ S

    The range algorithm exploits the geometric decay:
      A(y,[l,r],·)_p = λ^(p-r) · Σ_i λ^(r-i) k_i⊗v_i  — one decayed sum
    shared by all outputs ⇒ O((L1+L2)·dk·dv) per tile, satisfying the
    framework's efficiency requirement (T(U,U) quasilinear in U).
    """

    def __init__(self, wq, wk, wv, lam: float = 0.97):
        self.wq, self.wk, self.wv = wq, wk, wv
        self.lam = lam
        self.dk = wk.shape[1]
        self.dv = wv.shape[1]

    # -- projections
    def _kv(self, y):  # y (..., D) -> k (..., dk), v (..., dv)
        return y @ self.wk, y @ self.wv

    def init_state(self, batch, length):
        return jnp.zeros((batch, length, self.dk, self.dv), _F32)

    def cont_diag(self, y_i, i):
        k, v = self._kv(y_i.astype(_F32))
        return k[..., :, None] * v[..., None, :]  # (B, dk, dv)

    def range_alg(self, y_seg, in_lo, out_offsets):
        k, v = self._kv(y_seg.astype(_F32))  # (B, U, dk/dv)
        U = y_seg.shape[1]
        # decayed sum anchored at the LAST input position r = in_lo+U-1:
        w = self.lam ** jnp.arange(U - 1, -1, -1, dtype=_F32)  # λ^(r-i)
        S = jnp.einsum("u,buk,buv->bkv", w, k, v)
        scale = self.lam ** out_offsets.astype(_F32)  # λ^(p-r), p>r
        return scale[None, :, None, None] * S[:, None]  # (B, U2, dk, dv)

    def agg(self, b, x):
        return b + x

    def read(self, b_i, y_i):
        q = (y_i.astype(_F32) @ self.wq)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-6)
        return jnp.einsum("bk,bkv->bv", q, b_i)

    # ------------------------------------------------------------ oracles
    def naive(self, ys):
        """O(L²) direct evaluation of mixer(y)_j (B, L, dv)."""
        B, L, _ = ys.shape
        k, v = self._kv(ys.astype(_F32))
        out = []
        for j in range(L):
            S = jnp.zeros((B, self.dk, self.dv), _F32)
            for i in range(j + 1):
                S = S + (self.lam ** (j - i)) * (k[:, i, :, None] * v[:, i, None, :])
            out.append(self.read(S, ys[:, j]))
        return jnp.stack(out, axis=1)

    def recurrent(self, ys):
        """O(L·dk·dv) RNN-mode oracle: S_j = λ·S_{j-1} + k_j⊗v_j."""
        B, L, _ = ys.shape
        k, v = self._kv(ys.astype(_F32))
        S = jnp.zeros((B, self.dk, self.dv), _F32)
        out = []
        for j in range(L):
            S = self.lam * S + k[:, j, :, None] * v[:, j, None, :]
            out.append(self.read(S, ys[:, j]))
        return jnp.stack(out, axis=1)
