"""The generic Flash Inference framework — paper §4 / Algorithm 4.

Any mixer that is

  P.1 contribution-based:  mixer(y)_j = read(agg(cont(y,1,j) … cont(y,j,j)))
      with ASSOCIATIVE agg over an intermediate state space X, and
  P.2 query-independent:   cont(y,i,·) depends only on y_{1..i},

admits the fractal tile schedule with a black-box range algorithm

  A(y, [l,r], [l',r'])_p = agg(cont(y,l,p), …, cont(y,r,p))   (r < l').

Two drivers live here:

* :class:`GenericFlashEngine` — the PRODUCTION engine: a jitted,
  device-resident schedule walker (core/schedule.ScheduleWalker — the
  same machinery FlashEngine runs Hyena on) over a stack of
  ``GenericMixer`` levels interleaved with per-position blocks.  Donated
  pytree states, per-slot positions, ``schedule_segment``-keyed fused
  chunks (O(log L) cached programs), ``prefill`` / ``prefill_slot`` /
  ``decode_chunk`` / ``server_chunk`` — the full serving surface, so
  ``serving.GenericServer`` batches it continuously like the LCSM
  backend.

* :class:`ReferenceGenericEngine` — the original unjitted Python loop
  over Algorithm 4, kept as the documented SLOW REFERENCE the production
  engine is differentially tested against (tests/test_generic_schedule,
  tests/test_generic_framework).

``GatedLinearAttention`` instantiates the class for a non-convolution
member (the paper's "and Beyond"): cont(y,i,j) = λ^{j-i}·(k_i ⊗ v_i),
agg = +, read_j(S) = q_j·S — with an O((L1+L2)·d_k·d_v) range algorithm
exploiting the geometric decay (vs the naive L1·L2·d_k·d_v).
``models/gla.py`` builds a full language model out of it.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tau as tau_mod
from repro.core.schedule import (ScheduleWalker, ceil_pow2, slice_rows,
                                 tree_slice_rows, tree_update_rows,
                                 update_rows, write_next_rows,
                                 write_slot_rows)
from repro.core.tiling import largest_pow2_divisor
from repro.obs import trace as _obs

_F32 = jnp.float32


class GenericMixer(Protocol):
    """P.1 ∧ P.2 mixer over inputs y (B, L, D_in).

    The intermediate state space X is an arbitrary pytree whose leaves
    carry leading dims (B, ...) per position; ``agg`` must be associative
    and elementwise over the leading dims, and ``init_state`` must return
    the agg-neutral element at every position.  Position arguments
    (``i`` / ``in_lo``) are 0-based buffer indices — Python ints under the
    reference engine, traced (B,) int32 vectors under the production
    engine; mixers that don't need absolute positions ignore them.
    """

    def init_state(self, batch: int, length: int) -> Any:
        """Zero (agg-neutral) state buffer: pytree with leading (B, L)."""

    def cont_diag(self, y_i: jnp.ndarray, i) -> Any:
        """cont(y, i, i): contribution of position i to itself (X-valued,
        leading dim B)."""

    def range_alg(self, y_seg: jnp.ndarray, in_lo, out_offsets: jnp.ndarray) -> Any:
        """A(y, [in_lo, in_lo+U), outputs at in_lo+U-1+out_offsets):
        y_seg (B, U, D_in); out_offsets (U2,) 1-based distances past the
        last input.  Returns X-valued (B, U2, ...).  The framework's
        efficiency requirement (§4): T(U, U2) must be quasilinear in
        U + U2, not U·U2."""

    def agg(self, b: Any, x: Any) -> Any:
        """Associative aggregation (elementwise over leading dims)."""

    def read(self, b_i: Any, y_i: jnp.ndarray) -> jnp.ndarray:
        """Map state at a finalized position to the mixer output (B, D_out).
        y_i is the position's own input (available at read time — P.2 only
        constrains *contributions*, not the read)."""

    def prefill_states(self, ys: jnp.ndarray) -> Any:
        """FINALIZED states at every prompt position: leaves (B, P, ...)
        with entry t = agg(cont(y,0,t) … cont(y,t,t)).  The static
        (teacher-forced) path — the generic analogue of the LCSM engine's
        FFT prefill; only used by ``prefill``/``prefill_slot``, so a
        sequential scan is fine."""


class GenericModel(Protocol):
    """What GenericFlashEngine needs from a model (see models/gla.py).

    The engine drives M mixer levels interleaved with per-position
    blocks:  a[0] = token embeddings;  z[l] = mixer_l(a[l]);
    a[l+1] = block_l(z[l], a[l]);  advance samples from a[M].
    """

    a0_width: int
    n_levels: int
    widths: Sequence[int]  # widths of a[1..M]

    def mixers(self, params: Any) -> Sequence[GenericMixer]:
        """One parameter-bound mixer per level (rebuilt inside traces)."""

    def block(self, params: Any, level: int, z: jnp.ndarray,
              y: jnp.ndarray) -> jnp.ndarray:
        """Per-position block: z (B, T, D_out) mixer output, y (B, T, D_in)
        the level's own input rows.  Returns (B, T, width_{level+1})."""

    def advance(self, params: Any, a_top: jnp.ndarray,
                rng: jax.Array) -> tuple[jnp.ndarray, jnp.ndarray]:
        """a_top (B, width_M) at the just-finalized position.  Returns
        (next a[0] entry (B, a0_width), emitted token (B,) int32)."""


class GenericState(NamedTuple):
    """Pure buffer state for the generic engine.  ``a`` mirrors
    EngineState.a; ``s`` holds one mixer-state pytree per level (leaves
    (B, Lbuf, ...)).  Positions are NOT part of it — every jitted piece
    takes an explicit per-slot position vector (see core/schedule)."""

    a: tuple[jnp.ndarray, ...]  # level l: (B, Lbuf, width_l)
    s: tuple[Any, ...]          # level l (1-based, stored at l-1)


def _apply_tile(mix: GenericMixer, s_l, p: jnp.ndarray, contrib, mask,
                U: int, Lbuf: int):
    """Aggregate ``contrib`` (leaves (B, U, ...)) into rows p+1 .. p+U of
    the level state ``s_l``, per slot, clipped at the horizon and masked.

    The LCSM engine clips spilling tiles by scatter-ADDING zeros; a
    generic ``agg`` has no such absorbing element, so instead the window
    is clamped to stay in-bounds (start = min(p+1, Lbuf-U)), ``agg`` is
    applied on the whole slice, and out-of-tile rows keep their old value
    via a select — O(U) work either way, exact clipping."""
    wstart = jnp.minimum(p + 1, Lbuf - U)                      # (B,)
    rel = wstart[:, None] + jnp.arange(U)[None, :] - (p + 1)[:, None]
    valid = (rel >= 0) & mask[:, None]                          # (B, U)
    idx = jnp.clip(rel, 0, U - 1)
    seg = tree_slice_rows(s_l, wstart, U)
    take = jax.tree.map(
        lambda c: jax.vmap(lambda row, i: row[i])(c, idx), contrib)
    new = mix.agg(seg, take)
    merged = jax.tree.map(
        lambda n, o: jnp.where(
            valid.reshape(valid.shape + (1,) * (n.ndim - 2)), n, o),
        new, seg)
    return tree_update_rows(s_l, wstart, merged)


class LongConvMixer:
    """GenericMixer for one long-convolution (LCSM) level — the bridge
    that runs FlashEngine's hot path through the generic framework:
    state ``s_l`` is the (B, Lbuf, C) f32 contribution accumulator,
    ``cont(y,i,j) = y_i · rho[j-i]``, ``agg`` is +, and ``read`` returns
    the finalized accumulator row.  The range algorithm is τ with cached
    time-domain filter prefixes AND DFTs per pow2 tile side (the same
    §5.3/§5.4 dispatch FlashEngine uses — no per-trace irfft filter
    reconstruction), a causal-FFT tail for the rectangular prefill
    spill, and :func:`tau.tau_offsets` for anything else.

    Contractions live in core/tau.py — this module is FC003-pinned to
    mul+sum (GLA bit-identity)."""

    def __init__(self, rho: jnp.ndarray, *, direct_max: int = 32):
        self.rho = jnp.asarray(rho, jnp.float32)  # (L, C), L = Lbuf
        self.direct_max = direct_max
        max_tile = max(1, self.rho.shape[0] // 2)
        self._rho_f = tau_mod.make_rho_dfts(self.rho, max_tile)
        self._rho_pre = tau_mod.make_rho_prefixes(self.rho, max_tile)

    @property
    def conv_size(self) -> int:
        return self.rho.shape[1]

    def tile_filter(self, U: int) -> jnp.ndarray:
        """Time-domain rho[:2U] (cached for pow2 U <= Lbuf/2)."""
        pre = self._rho_pre.get(U)
        return self.rho[: 2 * U] if pre is None else pre

    def init_state(self, batch: int, length: int):
        return jnp.zeros((batch, length, self.conv_size), jnp.float32)

    def cont_diag(self, y_i, i):
        del i  # translation-invariant: the diagonal lag is always 0
        return y_i.astype(jnp.float32) * self.rho[0]

    def range_alg(self, y_seg, in_lo, out_offsets):
        del in_lo  # translation-invariant: only the lags matter
        U = y_seg.shape[-2]
        if not isinstance(out_offsets, jax.core.Tracer):
            offs = np.asarray(out_offsets)
            n = offs.shape[0]
            if np.array_equal(offs, np.arange(1, n + 1)):
                if n == U:
                    # Square Alg.-2 gray tile: §5.3 hybrid dispatch with
                    # the cached prefix/DFT pair.
                    return tau_mod.tau_hybrid(
                        y_seg, self.tile_filter(U), self._rho_f.get(U),
                        direct_max=self.direct_max)
                # Rectangular spill [i+1, i+n] (prefill): one causal FFT
                # conv over the segment, future tail kept.
                z = tau_mod.conv_causal_fft(
                    y_seg.astype(jnp.float32), self.rho[None],
                    out_len=U + n)
                return z[..., U:, :].astype(y_seg.dtype)
        return tau_mod.tau_offsets(y_seg, self.rho, out_offsets)

    def agg(self, b, x):
        return b + x

    def read(self, b_i, y_i):
        del y_i
        return b_i

    def prefill_states(self, ys):
        return tau_mod.conv_causal_fft(ys.astype(jnp.float32),
                                       self.rho[None])


class GenericFlashEngine(ScheduleWalker):
    """Production Algorithm-4 engine: the generic mixer framework on the
    shared fractal-schedule machinery (core/schedule).

    Same surface as FlashEngine — ``init_state`` / ``set_first`` /
    ``prefill`` / ``prefill_slot`` / ``generate(chunk_size=K)`` /
    ``decode_chunk`` / ``server_chunk`` / per-step ``red_step`` /
    ``gray_step`` — over :class:`GenericState` pytrees.  All step/chunk
    functions are jitted with ``donate_argnums`` on the state (buffers
    alias in place; callers must thread the returned state), and fused
    chunk programs are cached per schedule segment: O(log L) distinct
    programs over a whole generation.  Buffers are sized
    ``Lbuf = prompt_max + ceil_pow2(gen_max)`` so every gray tile fits.
    """

    def __init__(self, model: GenericModel, params: Any, *, batch: int,
                 gen_max: int, prompt_max: int = 0, dtype=jnp.float32,
                 gray_impl: str = "xla", chunk_size: int = 1):
        assert chunk_size >= 1
        assert gray_impl in ("xla", "pallas")
        self.model = model
        self.params = params
        self.batch = batch
        self.dtype = dtype
        self.strategy = "flash"  # the generic engine has no Ω(L²) baselines
        self.gray_impl = gray_impl
        self.chunk_size = chunk_size
        self.Lbuf = prompt_max + ceil_pow2(max(gen_max, 1))
        self.M = model.n_levels
        assert len(model.widths) == self.M
        self._init_schedule_dispatch()
        self._jit_prefill = jax.jit(self._prefill_rows)
        self._jit_prefill_slot = jax.jit(self._prefill_slot_impl,
                                         donate_argnums=(1,))

    # ------------------------------------------------------------------ state
    def init_state(self) -> GenericState:
        m = self.model
        a = tuple(jnp.zeros((self.batch, self.Lbuf, w), self.dtype)
                  for w in (m.a0_width,) + tuple(m.widths))
        s = tuple(mix.init_state(self.batch, self.Lbuf)
                  for mix in m.mixers(self.params))
        return GenericState(a=a, s=s)

    def set_first(self, state: GenericState, a0_first: jnp.ndarray) -> GenericState:
        a = list(state.a)
        a[0] = a[0].at[:, 0].set(a0_first.astype(self.dtype))
        return state._replace(a=tuple(a))

    # ------------------------------------------------------- red cells + block
    def _red_pass(self, params, state: GenericState, p, rng):
        """Finalize per-slot positions p (B,) across all levels, then advance
        (sample) every slot — the generic Algorithm-4 red cell: agg the
        diagonal contribution into the position's state, read, block."""
        m = self.model
        a = list(state.a)
        s = list(state.s)
        top = None
        for l, mix in enumerate(m.mixers(params)):
            y_p = slice_rows(a[l], p, 0, 1, a[l].shape[-1])[:, 0]  # (B, D)
            s_p = jax.tree.map(lambda leaf: leaf[:, 0],
                               tree_slice_rows(s[l], p, 1))
            s_p = mix.agg(s_p, mix.cont_diag(y_p, p))
            s[l] = tree_update_rows(
                s[l], p, jax.tree.map(lambda x: x[:, None], s_p))
            z_p = mix.read(s_p, y_p)                               # (B, D_out)
            out = m.block(params, l, z_p[:, None], y_p[:, None])   # (B, 1, w)
            out = out.astype(self.dtype)
            a[l + 1] = update_rows(a[l + 1], p, out)
            top = out[:, 0]
        a0_next, token = m.advance(params, top, rng)
        a[0] = write_next_rows(a[0], p, a0_next.astype(self.dtype), self.Lbuf)
        return self._shard_state(GenericState(a=tuple(a), s=tuple(s))), token

    # ------------------------------------------------------------- gray tiles
    def _gray_tile(self, params, state: GenericState, p, mask, *, U: int):
        """Per-slot range-algorithm call: contributions of a[b, p_b-U+1 .. p_b]
        to states at positions p_b+1 .. p_b+U (tile side U, static).

        GATHERED-ROW-SET body (ScheduleWalker's batched-dispatch contract):
        ``slice_rows`` *gathers* each slot's U input rows with per-slot
        clamped dynamic slices, the range algorithm runs unconditionally
        on the gathered (B, U, D) sub-batch, and ``_apply_tile``
        *scatters* the contributions back through a clamped window +
        select under ``mask`` (B,) bool — deselected rows keep their old
        value EXACTLY (a select, not an add: a generic ``agg`` has no
        absorbing zero), so an all-False-mask call is a fully bitwise
        no-op and the batched server dispatch can apply every possible
        side per step.  ``params`` is traced (walker-threaded): the mixer
        weights stay jit arguments instead of being baked into every
        cached tile/chunk program as constants.

        ``gray_impl="pallas"`` routes :class:`LongConvMixer` levels in
        the direct τ regime through the fused select-mode Pallas kernel
        (kernels/gray_tile.py) — gather + τ + clamped-window select
        merge in one program, bitwise vs this body."""
        m = self.model
        s = list(state.s)
        start = p - U + 1  # (B,); >= 0 for any live slot (U | rel step)
        offs = jnp.arange(1, U + 1)
        for l, mix in enumerate(m.mixers(params)):
            plan = self._gray_plan(mix, U, state.a[l].shape[-1])
            if plan is not None and plan.fused:
                from repro.kernels import ops as kops

                s[l] = kops.gray_tile_apply(
                    [state.a[l]], [s[l]], mix.tile_filter(U)[None], p,
                    mask, conv_starts=[0], Lbuf=self.Lbuf, mode="select",
                    slot_block=plan.slot_block)[0]
                continue
            y_seg = slice_rows(state.a[l], start, 0, U, state.a[l].shape[-1])
            contrib = mix.range_alg(y_seg, start, offs)  # (B, U, ...)
            s[l] = _apply_tile(mix, s[l], p, contrib, mask, U, self.Lbuf)
        return self._shard_state(state._replace(s=tuple(s)))

    def _gray_plan(self, mix, U: int, a_width: int):
        """Fused-dispatch decision for one level (trace-time), or None.
        Only LongConvMixer levels whose input plane IS the conv input
        (full width, conv_start 0) qualify — and only in the direct τ
        regime, where the fused kernel is bitwise vs ``range_alg``."""
        if self.gray_impl != "pallas" or not isinstance(mix, LongConvMixer):
            return None
        if a_width != mix.conv_size:
            return None
        from repro.kernels.heuristic import gray_plan

        return gray_plan(U=U, C=mix.conv_size, batch=self.batch,
                         widths=[a_width], Lbuf=self.Lbuf,
                         direct_max=mix.direct_max)

    def _obs_gray_labels_impl(self, U: int) -> tuple[str, str]:
        """Flashtrace (impl, tau-regime) labels for side U, mirroring the
        per-level dispatch in _gray_tile: "pallas" when every level's plan
        fuses, "mixed" when only some do, else "xla".  Non-conv mixers
        (GLA) have no τ crossover — their tiles are range-algorithm calls,
        labelled "range_alg".  Host-only — never traced."""
        m = self.model
        aw = [m.a0_width] + list(m.widths)  # a[l] plane widths
        mixers = m.mixers(self.params)
        fused = [(p := self._gray_plan(mix, U, aw[l])) is not None
                 and p.fused for l, mix in enumerate(mixers)]
        impl = ("pallas" if fused and all(fused)
                else "mixed" if any(fused) else "xla")
        dmaxes = [mix.direct_max for mix in mixers
                  if isinstance(mix, LongConvMixer)]
        if not dmaxes:
            regime = "range_alg"
        else:
            regime = "direct" if U <= min(dmaxes) else "fft"
        return (impl, regime)

    # ---------------------------------------------------------------- prefill
    def _prefill_rows(self, params, a0_prompt: jnp.ndarray, plen, rng):
        """Teacher-forced prompt ingestion on fresh zero buffers: per level,
        the mixer's static path (``prefill_states``) finalizes the prompt
        rows, ONE range-algorithm call spills the whole prompt's
        contributions into every future position (the generic analogue of
        the LCSM engine's Massaroli Lemma-2.1 eager spill), and the block
        runs full-width.  Ends with an ``advance`` from the last prompt
        position plen-1 so the first emitted token is prompt-conditioned.

        ``a0_prompt`` may be right-padded with zero rows past the TRACED
        true length ``plen`` (prompt-length bucketing).  Exactness leans on
        the mixer contract that ``cont`` of an all-zero input row is
        agg-neutral (GLA: k=v=0): then ``prefill_states`` rows past plen
        are exactly the finalized-prompt state carried forward — i.e. the
        spill values those positions need — and the padded ``range_alg``
        call spills the same aggregate the unpadded one would.  Junk block
        outputs at padded rows are masked to zero before they become the
        next level's input."""
        m = self.model
        Bp, P, _ = a0_prompt.shape
        keep = jnp.arange(P) < plen  # (P,) true-prompt-row mask
        p_last = jnp.broadcast_to(jnp.asarray(plen - 1, jnp.int32), (Bp,))
        a = [jnp.zeros((Bp, self.Lbuf, w), self.dtype)
             for w in (m.a0_width,) + tuple(m.widths)]
        mixers = m.mixers(params)
        s = [mix.init_state(Bp, self.Lbuf) for mix in mixers]
        a[0] = a[0].at[:, :P].set(a0_prompt.astype(self.dtype))
        for l, mix in enumerate(mixers):
            y = a[l][:, :P]
            states = mix.prefill_states(y)  # leaves (Bp, P, ...)
            s[l] = jax.tree.map(
                lambda big, rows: jax.lax.dynamic_update_slice(
                    big, rows.astype(big.dtype), (0,) * big.ndim),
                s[l], states)
            if P < self.Lbuf:
                spill = mix.range_alg(
                    y, jnp.zeros((Bp,), jnp.int32),
                    jnp.arange(1, self.Lbuf - P + 1))
                tail = jax.tree.map(lambda leaf: leaf[:, P:], s[l])
                tail = mix.agg(tail, spill)
                s[l] = jax.tree.map(
                    lambda big, t: jax.lax.dynamic_update_slice(
                        big, t.astype(big.dtype),
                        (0, P) + (0,) * (big.ndim - 2)),
                    s[l], tail)
            z = jax.vmap(mix.read, in_axes=1, out_axes=1)(states, y)
            out = m.block(params, l, z, y)
            out = jnp.where(keep[None, :, None], out, 0)
            a[l + 1] = a[l + 1].at[:, :P].set(out.astype(self.dtype))
        top = slice_rows(a[len(mixers)], p_last, 0, 1,
                         a[len(mixers)].shape[-1])[:, 0]
        a0_next, token = m.advance(params, top, rng)
        a[0] = write_next_rows(a[0], p_last, a0_next.astype(self.dtype),
                               self.Lbuf)
        return a, s, token

    def prefill(
        self, a0_prompt: jnp.ndarray, rng: jax.Array | None = None,
        *, bucket: bool = False,
    ) -> tuple[GenericState, jnp.ndarray]:
        """Full-batch prompt ingestion on fresh buffers; the tile schedule
        restarts at origin = P.  Returns (state, first sampled token (B,));
        subsequent tokens come from ``generate(..., origin=P)``.
        ``bucket=True`` pads to the pow2 length bucket — pass it when this
        prefill is the bitwise reference for a (bucketing) server
        admission."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        assert a0_prompt.shape[0] == self.batch
        plen = a0_prompt.shape[1]
        if bucket:
            a0_prompt, plen = self._bucket_prompt(a0_prompt)
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        a, s, token = self._jit_prefill(
            self.params, a0_prompt, jnp.asarray(plen, jnp.int32), rng)
        if rec is not None:
            self._obs_record_prefill(rec, "prefill", t0, a0_prompt.shape[1])
        return GenericState(a=tuple(a), s=tuple(s)), token

    def prefill_slot(
        self, state: GenericState, slot, a0_prompt: jnp.ndarray,
        rng: jax.Array | None = None, *, bucket: bool = True,
    ) -> tuple[GenericState, jnp.ndarray]:
        """Single-slot admission prefill for continuous batching: a batch-1
        prompt prefill on fresh buffers whose full Lbuf rows are then written
        into row ``slot`` of the batched state (no other slot is disturbed;
        slot reuse needs no separate reset because every row is
        overwritten).  The input state is donated.  Returns
        (state, first sampled token, scalar).

        Admission prefill BUCKETS by default (pad to pow2 + traced true
        length): the jit cache holds O(log prompt_max) programs instead of
        one per distinct prompt length."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        assert a0_prompt.shape[0] == 1
        plen = a0_prompt.shape[1]
        if bucket:
            a0_prompt, plen = self._bucket_prompt(a0_prompt)
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = self._jit_prefill_slot(
            self.params, state, jnp.asarray(slot, jnp.int32), a0_prompt,
            jnp.asarray(plen, jnp.int32), rng)
        if rec is not None:
            self._obs_record_prefill(rec, "prefill_slot", t0,
                                     a0_prompt.shape[1])
        return out

    def _prefill_slot_impl(self, params, state: GenericState, slot,
                           a0_prompt, plen, rng):
        a1, s1, token = self._prefill_rows(params, a0_prompt, plen, rng)
        a = tuple(write_slot_rows(big, one, slot)
                  for big, one in zip(state.a, a1))
        s = tuple(jax.tree.map(lambda b, o: write_slot_rows(b, o, slot),
                               big, one)
                  for big, one in zip(state.s, s1))
        return self._shard_state(GenericState(a=a, s=s)), token[0]


class ReferenceGenericEngine:
    """Algorithm 4 as a plain Python loop — the documented SLOW REFERENCE:
    autoregressive evaluation of a bare GenericMixer with L-1 calls to A
    (2^(P-1-q) of length 2^q each) + L diagonal conts, no jit, no batching
    of dispatches.  The production :class:`GenericFlashEngine` is
    differentially tested against it (and against the mixers' own
    naive/recurrent oracles)."""

    def __init__(self, mixer: GenericMixer, batch: int, length: int):
        self.mixer = mixer
        self.B = batch
        self.L = length

    def run(self, next_input, y0: jnp.ndarray):
        """next_input(outputs_so_far list, z_i (B, D_out)) -> y_{i+1} (B, D_in).
        Returns (ys (B, L, D_in), zs (B, L, D_out)) with z produced strictly
        causally (z_i read before y_{i+1} is requested)."""
        m = self.mixer
        b = m.init_state(self.B, self.L)
        ys = [y0]
        zs = []
        for i in range(1, self.L + 1):  # 1-based positions
            y_i = ys[-1]
            # red cell: finalize b_i
            bi = jax.tree.map(lambda leaf: leaf[:, i - 1], b)
            bi = m.agg(bi, m.cont_diag(y_i, i))
            b = jax.tree.map(
                lambda leaf, x: leaf.at[:, i - 1].set(x), b, bi)
            z_i = m.read(bi, y_i)
            zs.append(z_i)
            if i < self.L:
                # gray tile: inputs [i-U+1, i] -> outputs [i+1, i+U]
                U = largest_pow2_divisor(i)
                U_out = min(U, self.L - i)
                y_seg = jnp.stack(ys[i - U:], axis=1)  # (B, U, D_in)
                offs = jnp.arange(1, U_out + 1)
                contrib = m.range_alg(y_seg, i - U + 1, offs)
                seg = jax.tree.map(lambda leaf: leaf[:, i : i + U_out], b)
                seg = m.agg(seg, contrib)
                b = jax.tree.map(
                    lambda leaf, x: jax.lax.dynamic_update_slice_in_dim(
                        leaf, x, i, axis=1), b, seg)
                ys.append(next_input(zs, z_i))
        return jnp.stack(ys, axis=1), jnp.stack(zs, axis=1)


# ------------------------------------------------------- "and Beyond" (§6)
class GatedLinearAttention:
    """Gated linear attention as a P.1∧P.2 mixer.

    cont(y, i, j) = λ^(j-i) · (k_i ⊗ v_i)   ∈ X = R^{dk×dv}
    agg = +,   read_j(S) = normalize(q_j)ᵀ S

    The range algorithm exploits the geometric decay:
      A(y,[l,r],·)_p = λ^(p-r) · Σ_i λ^(r-i) k_i⊗v_i  — one decayed sum
    shared by all outputs ⇒ O((L1+L2)·dk·dv) per tile, satisfying the
    framework's efficiency requirement (T(U,U) quasilinear in U).

    ``norm`` (optional, (D,)) folds the pre-mixer RMS norm of a language-
    model layer into the mixer, so the engine can keep RAW activations in
    its buffers (models/gla.py uses this; the bare mixer of the original
    tests passes None and is unchanged).

    Reproducibility note: every contraction here is written as an explicit
    multiply + ``sum`` instead of ``dot``/``einsum``.  XLA CPU lowers small
    dots differently depending on what else shares their program (gemv
    runtime call vs fused loop — the same backend caveat PR 3 pinned for
    single-row matmuls), which made fused decode chunks drift ~1e-6 from
    the per-step path and broke the engine's chunked-vs-stepwise
    BIT-identity contract.  Mul+reduce lowers to the same in-order loop in
    every fusion context (tests/test_differential.py pins the contract);
    the arithmetic count is unchanged (2·U·dk·dv-ish per tile).
    """

    def __init__(self, wq, wk, wv, lam: float = 0.97, norm=None):
        self.wq, self.wk, self.wv = wq, wk, wv
        self.lam = lam
        self.norm = norm
        self.dk = wk.shape[1]
        self.dv = wv.shape[1]

    # -- projections
    def _in(self, y):  # pre-projection input map (optional fused RMS norm)
        y = y.astype(_F32)
        if self.norm is None:
            return y
        var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
        return y * jax.lax.rsqrt(var + 1e-6) * self.norm

    def _kv(self, y):  # y (..., D) -> k (..., dk), v (..., dv)
        yn = self._in(y)
        k = (yn[..., :, None] * self.wk).sum(-2)
        v = (yn[..., :, None] * self.wv).sum(-2)
        return k, v

    def init_state(self, batch, length):
        return jnp.zeros((batch, length, self.dk, self.dv), _F32)

    def cont_diag(self, y_i, i):
        k, v = self._kv(y_i)
        return k[..., :, None] * v[..., None, :]  # (B, dk, dv)

    def range_alg(self, y_seg, in_lo, out_offsets):
        k, v = self._kv(y_seg)  # (B, U, dk/dv)
        U = y_seg.shape[1]
        # decayed sum anchored at the LAST input position r = in_lo+U-1:
        w = self.lam ** jnp.arange(U - 1, -1, -1, dtype=_F32)  # λ^(r-i)
        S = ((k * w[None, :, None])[..., :, None] * v[..., None, :]).sum(1)
        scale = self.lam ** out_offsets.astype(_F32)  # λ^(p-r), p>r
        return scale[None, :, None, None] * S[:, None]  # (B, U2, dk, dv)

    def agg(self, b, x):
        return b + x

    def read(self, b_i, y_i):
        q = (self._in(y_i)[..., :, None] * self.wq).sum(-2)
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-6)
        return (q[..., :, None] * b_i).sum(-2)

    def step_state(self, S, y_i):
        """RNN-mode state update S_j = λ·S_{j-1} + k_j⊗v_j — the compact
        recurrence GLA happens to admit (the recurrent oracle; mixers
        without one are exactly why the schedule exists)."""
        k, v = self._kv(y_i)
        return self.lam * S + k[..., :, None] * v[..., None, :]

    def prefill_states(self, ys):
        """Finalized states at every position of a teacher-forced prompt:
        one lax.scan of the RNN recurrence (static path, prefill only)."""
        k, v = self._kv(ys)  # (B, P, dk/dv)
        kv = k[..., :, None] * v[..., None, :]  # (B, P, dk, dv)

        def step(S, x):
            S = self.lam * S + x
            return S, S
        _, states = jax.lax.scan(
            step, jnp.zeros((ys.shape[0], self.dk, self.dv), _F32),
            jnp.moveaxis(kv, 1, 0))
        return jnp.moveaxis(states, 0, 1)  # (B, P, dk, dv)

    # ------------------------------------------------------------ oracles
    def naive(self, ys):
        """O(L²) direct evaluation of mixer(y)_j (B, L, dv)."""
        B, L, _ = ys.shape
        k, v = self._kv(ys)
        out = []
        for j in range(L):
            S = jnp.zeros((B, self.dk, self.dv), _F32)
            for i in range(j + 1):
                S = S + (self.lam ** (j - i)) * (k[:, i, :, None] * v[:, i, None, :])
            out.append(self.read(S, ys[:, j]))
        return jnp.stack(out, axis=1)

    def recurrent(self, ys):
        """O(L·dk·dv) RNN-mode oracle: S_j = λ·S_{j-1} + k_j⊗v_j."""
        B, L, _ = ys.shape
        S = jnp.zeros((B, self.dk, self.dv), _F32)
        out = []
        for j in range(L):
            S = self.step_state(S, ys[:, j])
            out.append(self.read(S, ys[:, j]))
        return jnp.stack(out, axis=1)
