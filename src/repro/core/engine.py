"""Flash Inference engine for LCSM stacks — paper Algorithms 2 & 3.

The engine drives autoregressive generation for any model expressed as a
stack of M long-convolution *mixer levels* interleaved with per-position
*blocks* (paper §2.1 / §3.1.2):

    b[l, t]  = sum_{k<=t} conv_in(a[l-1])[k] (.) rho_l[t-k]      (mixer)
    a[l, t]  = block_l(b[l, t], a[0..l-1, t-w .. t])             (block)

with ``a[0]`` the token embeddings.  The engine owns the fractal tile
schedule, the τ dispatch, prompt handling (Massaroli Lemma 2.1 style
eager prefill then origin reset), and the across-layer batching of gray
tiles (Algorithm 3) — levels with equal conv width are stacked and the
tile convolution is evaluated once for the whole group.

Strategies (for the paper's baselines, §5):
  * ``flash`` — Algorithm 2/3 tiling, O(L log^2 L) per channel.
  * ``lazy``  — recompute each b[l, t] from the whole history, Omega(L^2).
  * ``eager`` — push each new activation to all future b's, Omega(L^2).

All three share the identical red-cell/block/advance path, so measured
differences isolate the mixer algorithm, as in the paper's Figure 2.

Shape-staticness: one jitted red-pass (position is a traced scalar) plus one
jitted gray-tile function *per tile side* — log2(L) specializations in total,
the XLA analogue of the paper's per-tile-size precompiled FlashFFT configs
(§5.4, engineering contribution #2).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Protocol, Sequence

import jax
import jax.numpy as jnp

from repro.core import tau as tau_mod
from repro.core.tiling import largest_pow2_divisor


def ceil_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass(frozen=True)
class LevelSpec:
    """One mixer level.

    width      — channels of this level's activation a[l].
    conv_start — first channel of a[l-1] fed to this level's convolution.
    conv_size  — number of channels convolved (== filter width).
    """

    width: int
    conv_start: int
    conv_size: int


class LCSMModel(Protocol):
    """What the engine needs from a model (see repro/models/hyena.py)."""

    ctx_window: int  # w: how many past positions blocks may read (short convs)
    a0_width: int
    levels: Sequence[LevelSpec]

    def filters(self, params: Any, length: int) -> Sequence[jnp.ndarray]:
        """Per level: (length, conv_size) data-independent filter rho_l."""

    def block(self, params: Any, level: int, b: jnp.ndarray,
              acts: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """b: (B, T, conv_size); acts[l'] : (B, w+T, width_l') for l' < level
        (entries for l' >= level are present but must not be read).
        Returns (B, T, width_level)."""

    def advance(self, params: Any, acts: Sequence[jnp.ndarray],
                rng: jax.Array) -> tuple[jnp.ndarray, jnp.ndarray]:
        """acts[l]: (B, w+1, width_l) ending at the just-finalized position.
        Returns (next a[0] entry (B, a0_width), emitted token (B,) int32)."""


class EngineState(NamedTuple):
    a: tuple[jnp.ndarray, ...]  # level l: (B, Lbuf, width_l)
    b: tuple[jnp.ndarray, ...]  # level l (1-based, stored at l-1): (B, Lbuf, conv_size_l)
    pos: jnp.ndarray            # next position to finalize (int32 scalar)


def _window(arr: jnp.ndarray, start, length: int) -> jnp.ndarray:
    """dynamic_slice along axis 1 with static length."""
    B = arr.shape[0]
    return jax.lax.dynamic_slice(
        arr, (0, start, 0), (B, length, arr.shape[2]))


class FlashEngine:
    """Orchestrates decode for one LCSM model instance.

    Buffers are sized ``Lbuf = prompt_max + ceil_pow2(gen_max)`` so every gray
    tile fits (for m < 2^P, m + lowbit(m) <= 2^P)."""

    def __init__(
        self,
        model: LCSMModel,
        params: Any,
        *,
        batch: int,
        gen_max: int,
        prompt_max: int = 0,
        dtype=jnp.float32,
        strategy: str = "flash",
        tau_impl: str = "hybrid",
        direct_max: int = 32,
        parallel_levels: bool = True,
        use_pallas: bool = False,
    ):
        assert strategy in ("flash", "lazy", "eager")
        assert tau_impl in ("hybrid", "direct", "fft", "pallas")
        self.model = model
        self.params = params
        self.batch = batch
        self.dtype = dtype
        self.strategy = strategy
        self.tau_impl = tau_impl
        self.direct_max = direct_max
        self.parallel_levels = parallel_levels
        self.use_pallas = use_pallas
        self.Lbuf = prompt_max + ceil_pow2(max(gen_max, 1))
        self.M = len(model.levels)

        # --- filters: rho[l] (Lbuf, C_l); rho_0 entries; per-size DFT cache.
        filts = model.filters(params, self.Lbuf)
        assert len(filts) == self.M
        self._rho = [jnp.asarray(f, jnp.float32) for f in filts]
        self._rho0 = [f[0] for f in self._rho]  # (C_l,)

        # --- group levels by conv width for across-layer batching (Alg. 3).
        groups: dict[int, list[int]] = {}
        for l, spec in enumerate(model.levels):
            assert self._rho[l].shape == (self.Lbuf, spec.conv_size)
            groups.setdefault(spec.conv_size, []).append(l)
        # group: (conv_size, level_ids, stacked rho (G, Lbuf, C))
        self._groups = [
            (csize, tuple(ls), jnp.stack([self._rho[l] for l in ls]))
            for csize, ls in sorted(groups.items())
        ]
        # Precomputed filter DFTs per tile size per group (App. C: 3->2 DFTs).
        self._rho_dfts = [
            tau_mod.make_rho_dfts(rho_g[:, None], self.Lbuf // 2)  # (G,1,2U,C)
            for (_, _, rho_g) in self._groups
        ]

        self._jit_red = jax.jit(self._red_pass)
        self._jit_gray: dict[int, Callable] = {}
        self._jit_lazy = jax.jit(self._lazy_fill)
        self._jit_eager = jax.jit(self._eager_push)

    # ------------------------------------------------------------------ state
    def init_state(self) -> EngineState:
        m = self.model
        a = tuple(
            jnp.zeros((self.batch, self.Lbuf, w), self.dtype)
            for w in [m.a0_width] + [s.width for s in m.levels]
        )
        b = tuple(
            jnp.zeros((self.batch, self.Lbuf, s.conv_size), jnp.float32)
            for s in m.levels
        )
        return EngineState(a=a, b=b, pos=jnp.int32(0))

    def set_first(self, state: EngineState, a0_first: jnp.ndarray) -> EngineState:
        a = list(state.a)
        a[0] = a[0].at[:, 0].set(a0_first.astype(self.dtype))
        return state._replace(a=tuple(a))

    # ------------------------------------------------------- red cells + block
    def _acts_windows(self, a: Sequence[jnp.ndarray], p, T: int):
        w = self.model.ctx_window
        # window [p - w, p + T - 1]; clamp via buffer padding: positions < 0
        # read garbage-zeros from start (buffers zero-initialized, and blocks
        # only consume weights * those entries — matches zero left-padding).
        start = jnp.maximum(p - w, 0)
        shift_ok = p >= w  # when p < w the window is shorter; emulate pad
        wins = []
        for arr in a:
            win = _window(arr, start, w + T)
            # if p < w, roll so that index w+T-1 still aligns with position
            # p+T-1: shift right by (w - p) and zero-fill the head.
            def pad_case(win=win, arr=arr):
                k = w - p
                rolled = jnp.roll(win, k, axis=1)
                mask = jnp.arange(w + T)[None, :, None] >= k
                return jnp.where(mask, rolled, 0)
            win = jax.lax.cond(shift_ok, lambda win=win: win, pad_case)
            wins.append(win)
        return wins

    def _red_pass(self, params, state: EngineState, p, rng):
        """Finalize position p across all levels, then advance (sample)."""
        m = self.model
        a = list(state.a)
        b = list(state.b)
        for l, spec in enumerate(m.levels):
            y_p = jax.lax.dynamic_slice(
                a[l], (0, p, spec.conv_start), (self.batch, 1, spec.conv_size)
            )  # conv input at p, from a[l-1] == a list index l
            b_p = jax.lax.dynamic_slice(
                b[l], (0, p, 0), (self.batch, 1, spec.conv_size))
            b_p = b_p + y_p.astype(jnp.float32) * self._rho0[l]
            acts = self._acts_windows(a, p, 1)
            out = m.block(params, l, b_p.astype(self.dtype), acts)  # (B,1,width)
            a[l + 1] = jax.lax.dynamic_update_slice(
                a[l + 1], out.astype(self.dtype), (0, p, 0))
        acts = self._acts_windows(a, p, 1)
        a0_next, token = m.advance(params, acts, rng)
        # dynamic_update_slice clamps out-of-range starts, which would silently
        # overwrite the last slot at the horizon — guard the final write.
        a[0] = jax.lax.cond(
            p + 1 < self.Lbuf,
            lambda a0: jax.lax.dynamic_update_slice(
                a0, a0_next[:, None, :].astype(self.dtype), (0, p + 1, 0)),
            lambda a0: a0,
            a[0],
        )
        return EngineState(a=tuple(a), b=tuple(b), pos=p + 1), token

    # ------------------------------------------------------------- gray tiles
    def _tau(self, y, rho2u, rho_f):
        impl = self.tau_impl
        U = y.shape[-2]
        if impl == "hybrid":
            return tau_mod.tau_hybrid(
                y, rho2u, rho_f, direct_max=self.direct_max,
                use_pallas=self.use_pallas)
        if impl == "direct":
            return tau_mod.tau_direct(y, rho2u)
        if impl == "pallas":
            from repro.kernels import ops as kops
            return kops.tile_conv(y, rho2u)
        return tau_mod.tau_fft(y, rho2u=rho2u, rho_f=rho_f)

    def _gray_tile(self, state: EngineState, p, *, U: int):
        """Contribution of a[., p-U+1 .. p] to b[., p+1 .. p+U] (tile side U,
        static).  Levels batched per conv-width group (Algorithm 3)."""
        a = state.a
        b = list(state.b)
        for gi, (csize, level_ids, rho_g) in enumerate(self._groups):
            rho2u = rho_g[:, None, : 2 * U]  # (G, 1, 2U, C)
            rho_f = self._rho_dfts[gi].get(U)
            ins = []
            for l in level_ids:
                spec = self.model.levels[l]
                seg = jax.lax.dynamic_slice(
                    a[l], (0, p - U + 1, spec.conv_start),
                    (self.batch, U, spec.conv_size))
                ins.append(seg)
            if self.parallel_levels:
                y = jnp.stack(ins)  # (G, B, U, C)
                out = self._tau(y, rho2u, rho_f)  # (G, B, U, C)
                outs = [out[i] for i in range(len(level_ids))]
            else:
                outs = [
                    self._tau(seg[None], rho2u[i : i + 1],
                              None if rho_f is None else rho_f[i : i + 1])[0]
                    for i, seg in enumerate(ins)
                ]
            for l, o in zip(level_ids, outs):
                cur = jax.lax.dynamic_slice(
                    b[l], (0, p + 1, 0), (self.batch, U, csize))
                b[l] = jax.lax.dynamic_update_slice(
                    b[l], cur + o.astype(jnp.float32), (0, p + 1, 0))
        return state._replace(b=tuple(b))

    # ----------------------------------------------------- baseline strategies
    def _lazy_fill(self, state: EngineState, p, origin):
        """Lazy: recompute b[l, p] = sum_{k<p} y_k rho_{p-k} from scratch."""
        b = list(state.b)
        idx = jnp.arange(self.Lbuf)
        for l, spec in enumerate(self.model.levels):
            y = jax.lax.dynamic_slice(
                state.a[l], (0, 0, spec.conv_start),
                (self.batch, self.Lbuf, spec.conv_size)).astype(jnp.float32)
            lag = p - idx  # rho index for input position k=idx
            valid = (lag >= 1) & (idx >= 0)
            rvals = jnp.take(self._rho[l], jnp.where(valid, lag, 0), axis=0)
            rvals = jnp.where(valid[:, None], rvals, 0.0)
            contrib = jnp.einsum("blc,lc->bc", y, rvals)
            b[l] = jax.lax.dynamic_update_slice(
                b[l], contrib[:, None, :], (0, p, 0))
        return state._replace(b=tuple(b))

    def _eager_push(self, state: EngineState, p):
        """Eager: push a[., p]'s contribution to every future b position."""
        b = list(state.b)
        idx = jnp.arange(self.Lbuf)
        for l, spec in enumerate(self.model.levels):
            y_p = jax.lax.dynamic_slice(
                state.a[l], (0, p, spec.conv_start),
                (self.batch, 1, spec.conv_size)).astype(jnp.float32)
            lag = idx - p
            valid = lag >= 1
            rvals = jnp.take(self._rho[l], jnp.where(valid, lag, 0), axis=0)
            rvals = jnp.where(valid[:, None], rvals, 0.0)  # (Lbuf, C)
            b[l] = b[l] + y_p * rvals[None]
        return state._replace(b=tuple(b))

    # ---------------------------------------------------------------- prefill
    def prefill(self, state: EngineState, a0_prompt: jnp.ndarray) -> EngineState:
        """Teacher-forced prompt ingestion (static FFT path) + eager spill of
        prompt contributions into all future b's (Massaroli Lemma 2.1), after
        which the tile schedule restarts at origin = P."""
        m = self.model
        B, P, _ = a0_prompt.shape
        a = list(state.a)
        b = list(state.b)
        a[0] = a[0].at[:, :P].set(a0_prompt.astype(self.dtype))
        w = m.ctx_window
        for l, spec in enumerate(m.levels):
            y_full = a[l][:, :, spec.conv_start : spec.conv_start + spec.conv_size]
            y = y_full[:, :P]
            # contributions of y[0..P-1] to *all* Lbuf outputs in one FFT:
            z = tau_mod.conv_causal_fft(
                y.astype(jnp.float32), self._rho[l][None], out_len=self.Lbuf)
            b[l] = b[l] + z.astype(jnp.float32)
            b_prompt = b[l][:, :P].astype(self.dtype)
            acts = [jnp.pad(arr[:, :P], ((0, 0), (w, 0), (0, 0))) for arr in a]
            out = m.block(self.params, l, b_prompt, acts)  # (B, P, width)
            a[l + 1] = a[l + 1].at[:, :P].set(out.astype(self.dtype))
        return EngineState(a=tuple(a), b=tuple(b), pos=jnp.int32(P))

    # ----------------------------------------------------------------- decode
    def generate(
        self,
        state: EngineState,
        n_tokens: int,
        *,
        origin: int = 0,
        rng: jax.Array | None = None,
    ) -> tuple[EngineState, jnp.ndarray]:
        """Host-side loop over positions (jitted pieces per tile size)."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        toks = []
        for step in range(n_tokens):
            p = origin + step
            rng, sub = jax.random.split(rng)
            if self.strategy == "lazy":
                state = self._jit_lazy(state, p, origin)
            state, tok = self._jit_red(self.params, state, p, sub)
            toks.append(tok)
            if self.strategy == "eager":
                state = self._jit_eager(state, p)
            elif self.strategy == "flash" and step + 1 < n_tokens:
                U = largest_pow2_divisor(step + 1)
                fn = self._jit_gray.get(U)
                if fn is None:
                    fn = jax.jit(functools.partial(self._gray_tile, U=U))
                    self._jit_gray[U] = fn
                state = self._gray_tile_guard(fn, state, p, U)
        return state, jnp.stack(toks, axis=1)

    def _gray_tile_guard(self, fn, state, p, U):
        if p + U >= self.Lbuf:  # tile would spill past the buffer: drop it —
            return state        # its outputs are beyond the generation horizon.
        return fn(state, p)

    # ------------------------------------------------- static (training) pass
    def forward_static(self, a0_seq: jnp.ndarray) -> list[jnp.ndarray]:
        """Reference full-sequence forward (the train-time path): returns the
        activation stack a[0..M] over T positions.  Used by tests as the
        ground truth the decode loop must reproduce exactly."""
        m = self.model
        B, T, _ = a0_seq.shape
        w = m.ctx_window
        a = [a0_seq.astype(self.dtype)]
        for l, spec in enumerate(m.levels):
            y = a[l][:, :, spec.conv_start : spec.conv_start + spec.conv_size]
            bl = tau_mod.conv_causal_fft(
                y.astype(jnp.float32), self._rho[l][None, :T])
            acts = [jnp.pad(arr, ((0, 0), (w, 0), (0, 0))) for arr in a]
            acts += [jnp.zeros((B, w + T, s.width), self.dtype)
                     for s in m.levels[l:]]
            out = m.block(self.params, l, bl.astype(self.dtype), acts)
            a.append(out.astype(self.dtype))
        return a
