"""Flash Inference engine for LCSM stacks — paper Algorithms 2 & 3.

The engine drives autoregressive generation for any model expressed as a
stack of M long-convolution *mixer levels* interleaved with per-position
*blocks* (paper §2.1 / §3.1.2):

    b[l, t]  = sum_{k<=t} conv_in(a[l-1])[k] (.) rho_l[t-k]      (mixer)
    a[l, t]  = block_l(b[l, t], a[0..l-1, t-w .. t])             (block)

with ``a[0]`` the token embeddings.  The engine owns the fractal tile
schedule, the τ dispatch, prompt handling (Massaroli Lemma 2.1 style
eager prefill then origin reset), and the across-layer batching of gray
tiles (Algorithm 3) — levels with equal conv width are stacked and the
tile convolution is evaluated once for the whole group.

Strategies (for the paper's baselines, §5):
  * ``flash`` — Algorithm 2/3 tiling, O(L log^2 L) per channel.
  * ``lazy``  — recompute each b[l, t] from the whole history, Omega(L^2).
  * ``eager`` — push each new activation to all future b's, Omega(L^2).

All three share the identical red-cell/block/advance path, so measured
differences isolate the mixer algorithm, as in the paper's Figure 2.

Positions are **per-slot**: every jitted piece takes a traced ``(B,)``
vector of positions, so each batch row (serving slot) can sit at its own
point of its own tile schedule.  Lockstep generation (``generate``) passes
a broadcast vector; the continuous-batching server (serving/lcsm_backend)
passes genuinely different per-slot positions and drives gray tiles per
(slot, tile-side) through ``gray_step``'s slot mask.

Shape-staticness: one jitted red-pass (positions are a traced vector) plus
one jitted gray-tile function *per tile side* — log2(L) specializations in
total, the XLA analogue of the paper's per-tile-size precompiled FlashFFT
configs (§5.4, engineering contribution #2).

Dispatch granularity: the per-step functions above are kept (and are the
K=1 path), but the hot loop is **device-resident chunked decode** —
``decode_chunk`` fuses K consecutive schedule steps (red pass + the gray
tiles their relative steps unlock, tile sides known at trace time from the
schedule segment) into ONE donated XLA computation, cached per segment
(O(log L) distinct segments for aligned power-of-two chunks, see
tiling.schedule_segment).  ``generate`` is a thin host loop over chunks;
host syncs drop from one per token to one per K tokens, and ``donate_argnums``
on every a/b buffer removes the full-state copy each dispatch used to pay.
K defaults to 1 (the per-step loop): fusing trades compile time for
dispatch overhead, which wins on real workloads (benchmarks/bench_decode.py
measures ~8x batch-1 tok/s at K=16 even on CPU) but loses in compile-bound
unit tests — pass ``chunk_size=K`` to turn it on.
All jitted step/chunk functions DONATE their state argument: after calling
them the passed-in ``EngineState`` is dead — callers must use the returned
state (every in-repo caller threads state linearly).

Multi-device: pass ``mesh=jax.sharding.Mesh(...)`` and the whole decode runs
sharded — serving slots (the batch axis of every a/b buffer) split over the
``data`` mesh axis, channels optionally over ``model`` (divisibility-guarded,
see launch/sharding.engine_state_specs).  Because every engine computation is
per-slot (vmapped rows) and τ is channel-separable, a data-sharded decode is
collective-free and BITWISE identical to the single-device one: each device
runs exactly the per-row programs it would run alone, and gray tiles of
different conv widths from different layers/slots still dispatch concurrently
per device shard (the paper's cross-layer parallelism at mesh scale).  Every
state-returning function is traced with an explicit sharding constraint on
the returned EngineState, so all cached programs — keyed by tile segment —
lower with output shardings equal to the input's and the donated buffers
alias IN PLACE on their home devices across chunks (no cross-device resharding
per dispatch).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Protocol, Sequence

import jax
import jax.numpy as jnp

from repro.core import tau as tau_mod
from repro.core.schedule import (  # noqa: F401 — ceil_pow2 re-exported
    ScheduleWalker, ceil_pow2, slice_rows, starts, update_rows,
    write_next_rows, write_slot_rows)
from repro.obs import trace as _obs


@dataclass(frozen=True)
class LevelSpec:
    """One mixer level.

    width      — channels of this level's activation a[l].
    conv_start — first channel of a[l-1] fed to this level's convolution.
    conv_size  — number of channels convolved (== filter width).
    """

    width: int
    conv_start: int
    conv_size: int


class LCSMModel(Protocol):
    """What the engine needs from a model (see repro/models/hyena.py)."""

    ctx_window: int  # w: how many past positions blocks may read (short convs)
    a0_width: int
    levels: Sequence[LevelSpec]

    def filters(self, params: Any, length: int) -> Sequence[jnp.ndarray]:
        """Per level: (length, conv_size) data-independent filter rho_l."""

    def block(self, params: Any, level: int, b: jnp.ndarray,
              acts: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """b: (B, T, conv_size); acts[l'] : (B, w+T, width_l') for l' < level
        (entries for l' >= level are present but must not be read).
        Returns (B, T, width_level)."""

    def advance(self, params: Any, acts: Sequence[jnp.ndarray],
                rng: jax.Array) -> tuple[jnp.ndarray, jnp.ndarray]:
        """acts[l]: (B, w+1, width_l) ending at the just-finalized position.
        Returns (next a[0] entry (B, a0_width), emitted token (B,) int32)."""


class EngineState(NamedTuple):
    """Pure buffer state.  Positions are NOT part of it — every jitted piece
    takes an explicit per-slot position vector, and the caller (lockstep
    ``generate`` or the continuous-batching server) owns the schedule."""

    a: tuple[jnp.ndarray, ...]  # level l: (B, Lbuf, width_l)
    b: tuple[jnp.ndarray, ...]  # level l (1-based, stored at l-1): (B, Lbuf, conv_size_l)


# Backwards-compatible aliases — the canonical definitions moved to
# repro.core.schedule (shared with the generic §4 engine).
_starts = starts
_slice_rows = slice_rows
_update_rows = update_rows


class FlashEngine(ScheduleWalker):
    """Orchestrates decode for one LCSM model instance.

    Buffers are sized ``Lbuf = prompt_max + ceil_pow2(gen_max)`` so every gray
    tile fits (for m < 2^P, m + lowbit(m) <= 2^P)."""

    def __init__(
        self,
        model: LCSMModel,
        params: Any,
        *,
        batch: int,
        gen_max: int,
        prompt_max: int = 0,
        dtype=jnp.float32,
        strategy: str = "flash",
        tau_impl: str = "hybrid",
        direct_max: int = 32,
        parallel_levels: bool = True,
        use_pallas: bool = False,
        gray_impl: str = "xla",
        chunk_size: int = 1,
        mesh=None,
        data_axis: str = "data",
        model_axis: str = "model",
    ):
        assert strategy in ("flash", "lazy", "eager")
        assert tau_impl in ("hybrid", "direct", "fft", "pallas")
        assert gray_impl in ("xla", "pallas")
        assert chunk_size >= 1
        self.model = model
        self.params = params
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self.batch = batch
        self.dtype = dtype
        self.strategy = strategy
        self.tau_impl = tau_impl
        self.direct_max = direct_max
        self.parallel_levels = parallel_levels
        self.use_pallas = use_pallas
        self.gray_impl = gray_impl
        self.chunk_size = chunk_size
        self.Lbuf = prompt_max + ceil_pow2(max(gen_max, 1))
        self.M = len(model.levels)

        # --- filters: rho[l] (Lbuf, C_l); rho_0 entries; per-size DFT cache.
        filts = model.filters(params, self.Lbuf)
        assert len(filts) == self.M
        self._rho = [jnp.asarray(f, jnp.float32) for f in filts]
        self._rho0 = [f[0] for f in self._rho]  # (C_l,)

        # --- group levels by conv width for across-layer batching (Alg. 3).
        groups: dict[int, list[int]] = {}
        for l, spec in enumerate(model.levels):
            assert self._rho[l].shape == (self.Lbuf, spec.conv_size)
            groups.setdefault(spec.conv_size, []).append(l)
        # group: (conv_size, level_ids, stacked rho (G, Lbuf, C))
        self._groups = [
            (csize, tuple(ls), jnp.stack([self._rho[l] for l in ls]))
            for csize, ls in sorted(groups.items())
        ]
        # Precomputed filter DFTs per tile size per group (App. C: 3->2 DFTs)
        # and the matching time-domain prefixes rho[:2U], so the direct-regime
        # dispatch never reconstructs the filter with an irfft inside a cached
        # decode/server program (tau_hybrid's fallback is exactly that).
        self._rho_dfts = [
            tau_mod.make_rho_dfts(rho_g[:, None], self.Lbuf // 2)  # (G,1,2U,C)
            for (_, _, rho_g) in self._groups
        ]
        self._rho_pres = [
            tau_mod.make_rho_prefixes(rho_g[:, None], self.Lbuf // 2)
            for (_, _, rho_g) in self._groups
        ]

        # --- mesh sharding: slots→data, channels→model (guarded).  Specs are
        # computed once from the buffer shapes; _shard_state pins them on the
        # traced output of every state-returning function so each cached
        # program keeps the donated buffers sharded in place, and params are
        # committed replicated so host pytrees aren't re-transferred per call.
        if mesh is not None:
            from repro.launch.sharding import engine_state_specs, replicated

            shapes = EngineState(
                a=tuple(jax.ShapeDtypeStruct((batch, self.Lbuf, w), dtype)
                        for w in [model.a0_width]
                        + [s.width for s in model.levels]),
                b=tuple(jax.ShapeDtypeStruct(
                    (batch, self.Lbuf, s.conv_size), jnp.float32)
                    for s in model.levels))
            self._state_specs = engine_state_specs(
                shapes, mesh, data_axis=data_axis, model_axis=model_axis)
            self.params = jax.device_put(
                params, jax.tree.map(lambda _: replicated(mesh), params))
        else:
            self._state_specs = None

        # Every step function donates its EngineState: the a/b buffers alias
        # input to output in XLA instead of being copied per dispatch.  The
        # schedule-walking dispatch (per-step jits, segment-keyed chunk
        # caches, server chunks) lives in core/schedule.ScheduleWalker.
        self._init_schedule_dispatch()
        # prompt length is a shape, so jax.jit retraces per distinct P —
        # the LCSM analogue of ServingEngine's per-length prefill cache.
        self._jit_prefill = jax.jit(self._prefill_rows)
        self._jit_prefill_slot = jax.jit(self._prefill_slot_impl,
                                         donate_argnums=(1,))

    # ------------------------------------------------------------------ state
    def _shard_state(self, state: EngineState) -> EngineState:
        """Pin the engine's slot/channel sharding on a TRACED state (no-op
        without a mesh).  Called at every state-returning trace's exit so
        output shardings always equal input shardings — the condition for
        XLA to honor donation across devices."""
        if self._state_specs is None:
            return state
        return jax.lax.with_sharding_constraint(state, self._state_specs)

    def place_state(self, state: EngineState) -> EngineState:
        """Commit a CONCRETE state onto the mesh (no-op without one)."""
        if self._state_specs is None:
            return state
        return jax.device_put(state, self._state_specs)

    def init_state(self) -> EngineState:
        m = self.model
        a = tuple(
            jnp.zeros((self.batch, self.Lbuf, w), self.dtype)
            for w in [m.a0_width] + [s.width for s in m.levels]
        )
        b = tuple(
            jnp.zeros((self.batch, self.Lbuf, s.conv_size), jnp.float32)
            for s in m.levels
        )
        return self.place_state(EngineState(a=a, b=b))

    def set_first(self, state: EngineState, a0_first: jnp.ndarray) -> EngineState:
        a = list(state.a)
        a[0] = a[0].at[:, 0].set(a0_first.astype(self.dtype))
        return state._replace(a=tuple(a))

    # ------------------------------------------------------- red cells + block
    def _acts_windows(self, a: Sequence[jnp.ndarray], p: jnp.ndarray, T: int):
        """Per-slot activation windows [p_b - w, p_b + T - 1] (left-padded
        with zeros when p_b < w, matching the static path's zero padding).

        p: (B,) int32.  Each returned window is (B, w+T, width)."""
        w = self.model.ctx_window
        start = jnp.maximum(p - w, 0)
        k = jnp.maximum(w - p, 0)  # per-slot left zero-pad
        wins = []
        for arr in a:
            def one(row, s, kk):
                win = jax.lax.dynamic_slice(
                    row, _starts(s, 0), (w + T, row.shape[1]))
                # shift right by kk and zero-fill the head so index w+T-1
                # always aligns with position p+T-1 (no-op when kk == 0).
                rolled = jnp.roll(win, kk, axis=0)
                mask = jnp.arange(w + T)[:, None] >= kk
                return jnp.where(mask, rolled, 0)
            wins.append(jax.vmap(one)(arr, start, k))
        return wins

    def _red_pass(self, params, state: EngineState, p, rng):
        """Finalize per-slot positions p (B,) across all levels, then advance
        (sample) every slot."""
        m = self.model
        a = list(state.a)
        b = list(state.b)
        fused_red = self.gray_impl == "pallas" and self.mesh is None
        for l, spec in enumerate(m.levels):
            if fused_red:
                # Fused gather+FMA red cell (kernels/gray_tile.py) —
                # bitwise vs the two dynamic slices + multiply-add below.
                from repro.kernels import ops as kops

                b_p = kops.red_pass_fma(a[l], b[l], self._rho0[l], p,
                                        conv_start=spec.conv_start)
            else:
                y_p = _slice_rows(a[l], p, spec.conv_start, 1, spec.conv_size)
                b_p = _slice_rows(b[l], p, 0, 1, spec.conv_size)
                b_p = b_p + y_p.astype(jnp.float32) * self._rho0[l]
            acts = self._acts_windows(a, p, 1)
            out = m.block(params, l, b_p.astype(self.dtype), acts)  # (B,1,width)
            a[l + 1] = _update_rows(a[l + 1], p, out.astype(self.dtype))
        acts = self._acts_windows(a, p, 1)
        a0_next, token = m.advance(params, acts, rng)
        if self.mesh is not None:
            # Pin the advance output replicated: otherwise GSPMD propagates
            # the sharded a[0]-write backward into the model's jax.random ops,
            # and legacy (non-partitionable) threefry generates DIFFERENT
            # values when its output is sharded — sampling models would lose
            # sharded-vs-unsharded bit-identity.  The advance is the tiny
            # per-token tail (B×D), so replicating it costs nothing.
            from jax.sharding import NamedSharding, PartitionSpec
            rep = NamedSharding(self.mesh, PartitionSpec())
            a0_next = jax.lax.with_sharding_constraint(a0_next, rep)
            token = jax.lax.with_sharding_constraint(token, rep)
        a[0] = write_next_rows(a[0], p, a0_next.astype(self.dtype), self.Lbuf)
        return self._shard_state(EngineState(a=tuple(a), b=tuple(b))), token

    # ------------------------------------------------------------- gray tiles
    def _gray_plan(self, U: int, csize: int, a_widths):
        """Trace-time fused-dispatch decision for one conv-width group, or
        None when ``gray_impl`` keeps the XLA body.  The fused kernel
        reproduces ``tau_direct``'s arithmetic bitwise, so only the
        direct-regime dispatches of the plain τ impls route through it:
        the tile_conv (``use_pallas``/``tau_impl="pallas"``) and FFT
        bodies round differently.  Disabled under a mesh — the
        interpret-mode pallas_call is not partition-aware (same guard as
        kernels/ops.short_conv)."""
        if self.gray_impl != "pallas" or self.mesh is not None:
            return None
        if self.tau_impl not in ("hybrid", "direct") or self.use_pallas:
            return None
        from repro.kernels.heuristic import gray_plan

        dmax = self.direct_max if self.tau_impl == "hybrid" else self.Lbuf
        # min_u=2: the U=1 lcsm tile is a bare multiply feeding the
        # accumulate, which XLA's CPU fusion emitter may contract to an
        # FMA depending on fusion context — unpinnable (heuristic.py).
        return gray_plan(U=U, C=csize, batch=self.batch, widths=a_widths,
                         Lbuf=self.Lbuf, direct_max=dmax, min_u=2)

    def _obs_gray_labels_impl(self, U: int) -> tuple[str, str]:
        """Flashtrace (impl, tau-regime) labels for side U, mirroring the
        real trace-time dispatch: impl is "pallas" when every conv-width
        group routes side U through the fused kernel (per _gray_plan),
        "mixed" when only some do, else "xla"; the regime label follows
        tau_hybrid's direct/FFT crossover.  Host-only — never traced."""
        m = self.model
        aw = [m.a0_width] + [s.width for s in m.levels]  # a[l] plane widths
        fused = [
            (p := self._gray_plan(U, csize, [aw[l] for l in level_ids]))
            is not None and p.fused
            for csize, level_ids, _ in self._groups]
        impl = ("pallas" if fused and all(fused)
                else "mixed" if any(fused) else "xla")
        if self.tau_impl == "fft":
            regime = "fft"
        elif self.tau_impl == "direct":
            regime = "direct"
        else:  # hybrid / pallas delegate to tau_hybrid's crossover
            regime = "direct" if U <= self.direct_max else "fft"
        return (impl, regime)

    def _tau(self, y, rho2u, rho_f):
        impl = self.tau_impl
        if impl == "hybrid":
            return tau_mod.tau_hybrid(
                y, rho2u, rho_f, direct_max=self.direct_max,
                use_pallas=self.use_pallas)
        if impl == "direct":
            return tau_mod.tau_direct(y, rho2u)
        if impl == "pallas":
            # The Pallas kernel is the *direct* form: its inner reduction is
            # unrolled U times (O(U^2) work, O(U) trace size), so routing
            # every tile side through it blows up both compile time and FLOPs
            # for large tiles.  tau_hybrid owns the direct/FFT crossover —
            # delegate so the rule lives in one place (§5.3 Pareto dispatch).
            return tau_mod.tau_hybrid(
                y, rho2u, rho_f, direct_max=self.direct_max, use_pallas=True)
        return tau_mod.tau_fft(y, rho2u=rho2u, rho_f=rho_f)

    def _gray_tile(self, params, state: EngineState, p, mask, *, U: int):
        """Per-slot contribution of a[b, p_b-U+1 .. p_b] to
        b[b, p_b+1 .. p_b+U] (tile side U, static).  Levels batched per
        conv-width group (Algorithm 3); slots with the same unlocked tile
        side share one τ evaluation.

        GATHERED-ROW-SET body (ScheduleWalker's batched-dispatch contract):
        ``_slice_rows(a[l], start, ...)`` *gathers* each slot's U input
        rows with per-slot clamped dynamic slices (masked-out slots may
        sit anywhere — the clamp makes their gather well-defined junk), τ
        runs unconditionally on the gathered (B, U, C) sub-batch, and the
        result *scatters* back through a masked add: ``mask`` (B,) bool
        zeroes the τ output of deselected slots before the scatter-add,
        so they are left untouched — bitwise, except that adding +0.0
        turns a stored -0.0 into +0.0.  No data-dependent control flow
        anywhere: that is what lets the server apply every possible tile
        side per step and select by mask.  ``params`` is the
        walker-threaded model pytree — unused here (LCSM tiles read only
        the precomputed filters/DFTs, host constants by design).

        ``gray_impl="pallas"`` routes direct-regime groups through the
        fused Pallas kernel (kernels/gray_tile.py: gather + τ + clipped
        scatter-add in one program, bitwise vs this body); FFT-regime
        tiles and non-direct τ impls keep the XLA chain, per-group, via
        the kernels/heuristic.py plan."""
        del params
        a = state.a
        b = list(state.b)
        start = p - U + 1  # (B,); >= 0 for any live slot (U | rel step)
        for gi, (csize, level_ids, rho_g) in enumerate(self._groups):
            rho2u = self._rho_pres[gi].get(U)  # (G, 1, 2U, C) cached prefix
            if rho2u is None:
                rho2u = rho_g[:, None, : 2 * U]
            rho_f = self._rho_dfts[gi].get(U)
            plan = self._gray_plan(U, csize, [a[l].shape[-1]
                                              for l in level_ids])
            if plan is not None and plan.fused:
                from repro.kernels import ops as kops

                new_b = kops.gray_tile_apply(
                    [a[l] for l in level_ids], [b[l] for l in level_ids],
                    rho2u[:, 0], p, mask,
                    conv_starts=[self.model.levels[l].conv_start
                                 for l in level_ids],
                    Lbuf=self.Lbuf, mode="lcsm",
                    slot_block=plan.slot_block)
                for l, nb in zip(level_ids, new_b):
                    b[l] = nb
                continue
            ins = []
            for l in level_ids:
                spec = self.model.levels[l]
                seg = _slice_rows(a[l], start, spec.conv_start, U,
                                  spec.conv_size)
                ins.append(seg)  # (B, U, C)
            if self.parallel_levels:
                y = jnp.stack(ins)  # (G, B, U, C)
                out = self._tau(y, rho2u, rho_f)  # (G, B, U, C)
                outs = [out[i] for i in range(len(level_ids))]
            else:
                outs = [
                    self._tau(seg[None], rho2u[i : i + 1],
                              None if rho_f is None else rho_f[i : i + 1])[0]
                    for i, seg in enumerate(ins)
                ]
            for l, o in zip(level_ids, outs):
                o = jnp.where(mask[:, None, None], o.astype(jnp.float32), 0.0)
                def add_tile(row, q, oo):
                    # scatter-add so tiles straddling the buffer horizon are
                    # clipped exactly: out-of-range outputs are zeroed (their
                    # positions are never generated) instead of dropping the
                    # whole tile, and the clamped index then adds 0.
                    idx = q + 1 + jnp.arange(U)
                    oo = jnp.where((idx < self.Lbuf)[:, None], oo, 0.0)
                    return row.at[jnp.minimum(idx, self.Lbuf - 1)].add(oo)
                b[l] = jax.vmap(add_tile)(b[l], p, o)
        return self._shard_state(state._replace(b=tuple(b)))

    # ----------------------------------------------------- baseline strategies
    def _lazy_fill(self, state: EngineState, p):
        """Lazy: recompute b[l, p_b] = sum_{k<p_b} y_k rho_{p_b-k} from the
        whole per-slot history.  p: (B,).  (The full recompute already
        includes any prompt prefix sitting in the buffer, so no origin
        bookkeeping is needed — each slot's value is complete on its own.)"""
        b = list(state.b)
        idx = jnp.arange(self.Lbuf)
        for l, spec in enumerate(self.model.levels):
            y = jax.lax.dynamic_slice(
                state.a[l], (0, 0, spec.conv_start),
                (self.batch, self.Lbuf, spec.conv_size)).astype(jnp.float32)
            lag = p[:, None] - idx[None, :]  # (B, Lbuf) rho index per input k
            valid = lag >= 1
            rvals = jnp.take(self._rho[l], jnp.where(valid, lag, 0), axis=0)
            rvals = jnp.where(valid[..., None], rvals, 0.0)  # (B, Lbuf, C)
            contrib = jnp.einsum("blc,blc->bc", y, rvals)
            b[l] = _update_rows(b[l], p, contrib[:, None, :])
        return self._shard_state(state._replace(b=tuple(b)))

    def _eager_push(self, state: EngineState, p):
        """Eager: push a[b, p_b]'s contribution to every future b position
        of its own slot.  p: (B,)."""
        b = list(state.b)
        idx = jnp.arange(self.Lbuf)
        for l, spec in enumerate(self.model.levels):
            y_p = _slice_rows(state.a[l], p, spec.conv_start, 1,
                              spec.conv_size).astype(jnp.float32)
            lag = idx[None, :] - p[:, None]  # (B, Lbuf)
            valid = lag >= 1
            rvals = jnp.take(self._rho[l], jnp.where(valid, lag, 0), axis=0)
            rvals = jnp.where(valid[..., None], rvals, 0.0)  # (B, Lbuf, C)
            b[l] = b[l] + y_p * rvals
        return self._shard_state(state._replace(b=tuple(b)))

    # ---------------------------------------------------------------- prefill
    def _prefill_rows(self, params, a0_prompt: jnp.ndarray, plen, rng):
        """Teacher-forced prompt ingestion (static FFT path) on FRESH zero
        buffers + eager spill of prompt contributions into all future b's
        (Massaroli Lemma 2.1), then a first ``advance`` from the last prompt
        position plen-1 — so the first emitted token is conditioned on the
        prompt, exactly like an autoregressive reference decode — whose
        a0 entry is written at plen.  Returns (a rows, b rows, token).

        ``a0_prompt`` may be right-padded with zero rows past the TRACED
        true length ``plen`` (prompt-length bucketing, see
        ScheduleWalker._bucket_prompt): zero rows contribute nothing to the
        convolutions, and the mask below zeroes the (junk) block outputs at
        padded positions before they feed the next level's convolution —
        positions < plen come out exactly as an unpadded prefill of the
        same FFT size would produce them."""
        m = self.model
        Bp, P, _ = a0_prompt.shape
        w = m.ctx_window
        keep = jnp.arange(P) < plen  # (P,) true-prompt-row mask
        p_last = jnp.broadcast_to(jnp.asarray(plen - 1, jnp.int32), (Bp,))
        a = [jnp.zeros((Bp, self.Lbuf, wd), self.dtype)
             for wd in [m.a0_width] + [s.width for s in m.levels]]
        b = [jnp.zeros((Bp, self.Lbuf, s.conv_size), jnp.float32)
             for s in m.levels]
        a[0] = a[0].at[:, :P].set(a0_prompt.astype(self.dtype))
        for l, spec in enumerate(m.levels):
            y = a[l][:, :P, spec.conv_start : spec.conv_start + spec.conv_size]
            # contributions of y[0..P-1] to *all* Lbuf outputs in one FFT:
            z = tau_mod.conv_causal_fft(
                y.astype(jnp.float32), self._rho[l][None], out_len=self.Lbuf)
            b[l] = b[l] + z.astype(jnp.float32)
            b_prompt = b[l][:, :P].astype(self.dtype)
            acts = [jnp.pad(arr[:, :P], ((0, 0), (w, 0), (0, 0))) for arr in a]
            out = m.block(params, l, b_prompt, acts)  # (Bp, P, width)
            out = jnp.where(keep[None, :, None], out, 0)
            a[l + 1] = a[l + 1].at[:, :P].set(out.astype(self.dtype))
        acts = self._acts_windows(a, p_last, 1)
        a0_next, token = m.advance(params, acts, rng)
        a[0] = write_next_rows(a[0], p_last, a0_next.astype(self.dtype),
                               self.Lbuf)
        return a, b, token

    def prefill(
        self, a0_prompt: jnp.ndarray, rng: jax.Array | None = None,
        *, bucket: bool = False,
    ) -> tuple[EngineState, jnp.ndarray]:
        """Full-batch prompt ingestion on fresh buffers; the tile schedule
        restarts at origin = P.  Returns (state, first sampled token (B,));
        subsequent tokens come from ``generate(..., origin=P)``.  (Takes no
        input state on purpose: a prompt defines the whole prefix, so any
        previously seeded state would be discarded anyway.)

        ``bucket=True`` pads the prompt to a pow2 length bucket before
        tracing (see _bucket_prompt) — pass it when this prefill serves as
        the bitwise reference for a server admission, which always buckets
        (a different pad can mean a different FFT size, hence different
        rounding)."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        assert a0_prompt.shape[0] == self.batch
        plen = a0_prompt.shape[1]
        if bucket:
            a0_prompt, plen = self._bucket_prompt(a0_prompt)
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        a, b, token = self._jit_prefill(
            self.params, a0_prompt, jnp.asarray(plen, jnp.int32), rng)
        if rec is not None:
            self._obs_record_prefill(rec, "prefill", t0, a0_prompt.shape[1])
        # full prefill builds fresh buffers from a replicated prompt, so the
        # one-time commit onto the mesh happens here (decode then donates the
        # sharded buffers in place).
        return self.place_state(EngineState(a=tuple(a), b=tuple(b))), token

    def prefill_slot(
        self, state: EngineState, slot, a0_prompt: jnp.ndarray,
        rng: jax.Array | None = None, *, bucket: bool = True,
    ) -> tuple[EngineState, jnp.ndarray]:
        """Single-slot admission prefill for continuous batching: a batch-1
        prompt prefill on fresh buffers whose full Lbuf rows are then written
        into row ``slot`` of the batched state (one dynamic_update_slice per
        buffer — no other slot is disturbed, and slot reuse needs no separate
        reset because every row is overwritten).  The input state is donated.
        Returns (state, first sampled token, scalar).

        Admission prefill BUCKETS by default: the prompt is padded to a pow2
        length (true length rides along traced), so this jit cache holds
        O(log prompt_max) programs instead of one per distinct prompt length
        a serving workload happens to contain."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        assert a0_prompt.shape[0] == 1
        plen = a0_prompt.shape[1]
        if bucket:
            a0_prompt, plen = self._bucket_prompt(a0_prompt)
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = self._jit_prefill_slot(
            self.params, state, jnp.asarray(slot, jnp.int32), a0_prompt,
            jnp.asarray(plen, jnp.int32), rng)
        if rec is not None:
            self._obs_record_prefill(rec, "prefill_slot", t0,
                                     a0_prompt.shape[1])
        return out

    def _prefill_slot_impl(self, params, state: EngineState, slot,
                           a0_prompt, plen, rng):
        a1, b1, token = self._prefill_rows(params, a0_prompt, plen, rng)
        a = tuple(write_slot_rows(big, one, slot)
                  for big, one in zip(state.a, a1))
        b = tuple(write_slot_rows(big, one, slot)
                  for big, one in zip(state.b, b1))
        return self._shard_state(EngineState(a=a, b=b)), token[0]

    # ---------------------------------------------------------------- decode
    # generate / decode_chunk / server_chunk / red_step / gray_step / … are
    # inherited from core/schedule.ScheduleWalker — the schedule-walking
    # half is shared with the generic §4 engine; only the red-pass and
    # gray-tile bodies above are LCSM-specific.

    # ------------------------------------------------- static (training) pass
    def forward_static(self, a0_seq: jnp.ndarray) -> list[jnp.ndarray]:
        """Reference full-sequence forward (the train-time path): returns the
        activation stack a[0..M] over T positions.  Used by tests as the
        ground truth the decode loop must reproduce exactly."""
        m = self.model
        B, T, _ = a0_seq.shape
        w = m.ctx_window
        a = [a0_seq.astype(self.dtype)]
        for l, spec in enumerate(m.levels):
            y = a[l][:, :, spec.conv_start : spec.conv_start + spec.conv_size]
            bl = tau_mod.conv_causal_fft(
                y.astype(jnp.float32), self._rho[l][None, :T])
            acts = [jnp.pad(arr, ((0, 0), (w, 0), (0, 0))) for arr in a]
            acts += [jnp.zeros((B, w + T, s.width), self.dtype)
                     for s in m.levels[l:]]
            out = m.block(self.params, l, bl.astype(self.dtype), acts)
            a.append(out.astype(self.dtype))
        return a
