"""Shared schedule-walking machinery for Flash-Inference engines.

The fractal tile schedule (paper §3.1, Algorithm 2) is mixer-agnostic:
what varies between the LCSM engine (``core/engine.FlashEngine``, long
convolutions, Algorithms 2/3) and the generic §4 engine
(``core/generic.GenericFlashEngine``, any P.1∧P.2 mixer, Algorithm 4) is
only *what a red cell and a gray tile compute* — never how the schedule
is walked, fused, cached, or dispatched.  This module owns that shared
half:

* **per-slot position vectors** — every jitted piece takes a traced
  ``(B,)`` vector of positions, so each batch row (serving slot) can sit
  at its own point of its own tile schedule;
* **per-step dispatch** — one jitted red pass, one jitted gray-tile
  function per tile side (log2(L) specializations), all donating their
  state so buffers alias in place instead of being copied per token;
* **``schedule_segment``-keyed chunk fusion** — ``decode_chunk`` fuses K
  schedule steps (red pass + the gray tiles the segment prescribes,
  sides static at trace time) into ONE donated XLA computation, cached
  per segment (O(log L) distinct programs for aligned pow2 chunks);
* **per-slot fused serving chunks** — ``server_chunk`` steps all slots K
  tokens with one dispatch, applying every possible tile side through a
  BATCHED gather/scatter formulation (compute-both-outcomes, select by
  mask — never data-dependent control flow), deferring the token readback
  to the chunk end.  The retired per-side ``lax.cond`` ladder survives as
  ``dispatch="reference"`` so the batched path stays pinned against it
  (tests/test_server_dispatch.py).

An engine subclasses :class:`ScheduleWalker` and provides:

  required attributes
    ``batch``       slots B (leading axis of every state buffer)
    ``Lbuf``        buffer horizon (positions per slot)
    ``params``      the model parameter pytree passed to ``_red_pass``
    ``strategy``    "flash" | "lazy" | "eager"
    ``chunk_size``  default K for ``generate``

  required methods (the mixer-specific half)
    ``_red_pass(params, state, p, rng) -> (state, tokens)``
        finalize per-slot positions ``p`` (B,) and advance every slot
    ``_gray_tile(params, state, p, mask, *, U) -> state``
        apply the side-``U`` tile at per-slot positions ``p`` to the
        slots selected by ``mask`` (B,) bool.  ``params`` is threaded
        (traced) so engines whose tiles read model parameters don't bake
        them into every cached program as constants; engines whose tiles
        only use derived host constants (the LCSM filters) ignore it.
        GATHERED-ROW-SET CONTRACT (what the batched server dispatch
        leans on): the body must (a) *gather* each slot's U input rows
        with clamped per-slot dynamic slices — rows of masked-out slots
        may sit at arbitrary positions, the slice just clamps — (b)
        compute contributions for the whole gathered sub-batch
        unconditionally, and (c) merge them back by masked scatter /
        select, so a call whose mask is all-False is a (bitwise, up to
        the sign of a scatter-added zero) no-op.  No body may branch on
        data — that is what lets the walker retire the per-side
        ``lax.cond`` ladder

  optional methods
    ``_lazy_fill(state, p)`` / ``_eager_push(state, p)``
        the Ω(L²) baseline strategies (engines that only implement
        "flash" simply omit them)
    ``_shard_state(state)``
        pin a sharding on a traced state (default: identity) — mesh-
        aware engines override so every cached program lowers with
        output shardings equal to its input's and donation aliases in
        place across devices

and calls ``_init_schedule_dispatch()`` at the end of its ``__init__``.

Every state-taking method here DONATES the state argument: after a call
the passed-in state is dead and callers must thread the returned one.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.tiling import largest_pow2_divisor, schedule_segment
from repro.obs import trace as _obs


def ceil_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def as_pos_vec(p, batch: int) -> jnp.ndarray:
    """Normalize a position argument to a (batch,) int32 vector."""
    p = jnp.asarray(p, jnp.int32)
    if p.ndim == 0:
        p = jnp.full((batch,), p, jnp.int32)
    return p


def starts(q: jnp.ndarray, *rest) -> tuple:
    """dynamic_slice start tuple mixing a traced index with literals: the
    literals are cast to the traced dtype — x64 mode would otherwise
    promote them to int64 and lax rejects the int32/int64 mix."""
    return (q,) + tuple(jnp.asarray(r, q.dtype) for r in rest)


def slice_rows(arr: jnp.ndarray, p: jnp.ndarray, start_ch: int,
               length: int, n_ch: int) -> jnp.ndarray:
    """Per-slot dynamic_slice: row b gets arr[b, p[b] : p[b]+length,
    start_ch : start_ch+n_ch].  Starts clamp like dynamic_slice."""
    return jax.vmap(
        lambda row, q: jax.lax.dynamic_slice(
            row, starts(q, start_ch), (length, n_ch)))(arr, p)


def update_rows(arr: jnp.ndarray, p: jnp.ndarray, val: jnp.ndarray) -> jnp.ndarray:
    """Per-slot dynamic_update_slice of val[b] at (p[b], 0)."""
    return jax.vmap(
        lambda row, q, v: jax.lax.dynamic_update_slice(row, v, starts(q, 0))
    )(arr, p, val)


def write_next_rows(arr: jnp.ndarray, p: jnp.ndarray, val: jnp.ndarray,
                    horizon: int) -> jnp.ndarray:
    """Per-slot write of val[b] at row p[b] + 1 — the a0 advance write.
    dynamic_update_slice clamps out-of-range starts, which would silently
    overwrite the last row at the horizon, so rows with p+1 >= horizon are
    left untouched instead (their positions are never generated)."""
    def one(row, q, v, ok):
        new = jax.lax.dynamic_update_slice(row, v[None], starts(q + 1, 0))
        return jnp.where(ok, new, row)
    return jax.vmap(one)(arr, p, val, p + 1 < horizon)


def write_slot_rows(big: jnp.ndarray, one: jnp.ndarray, slot) -> jnp.ndarray:
    """Write a batch-1 buffer's full rows into row ``slot`` of the batched
    buffer (one dynamic_update_slice — no other slot is disturbed): the
    admission-prefill splice."""
    return jax.lax.dynamic_update_slice(
        big, one.astype(big.dtype), starts(slot, *(0,) * (big.ndim - 1)))


def tree_slice_rows(tree, p: jnp.ndarray, length: int):
    """Pytree generalization of :func:`slice_rows` over full trailing dims:
    every leaf is (B, L, ...) and row b yields leaf[b, p[b] : p[b]+length]."""
    def one(leaf):
        return jax.vmap(
            lambda row, q: jax.lax.dynamic_slice(
                row, starts(q, *(0,) * (row.ndim - 1)),
                (length,) + row.shape[1:]))(leaf, p)
    return jax.tree.map(one, tree)


def tree_update_rows(tree, p: jnp.ndarray, val):
    """Pytree generalization of :func:`update_rows`: write val leaf rows
    (B, length, ...) into each (B, L, ...) leaf at per-slot positions p."""
    def one(leaf, v):
        return jax.vmap(
            lambda row, q, vr: jax.lax.dynamic_update_slice(
                row, vr.astype(row.dtype),
                starts(q, *(0,) * (row.ndim - 1))))(leaf, p, v)
    return jax.tree.map(one, tree, val)


class ScheduleWalker:
    """Schedule-walking half of a Flash-Inference engine (see module doc)."""

    # -- subclass-provided (declared for reference; see module docstring)
    batch: int
    Lbuf: int
    strategy: str
    chunk_size: int
    # server-tile dispatch mode: "batched" (gather/scatter mask-select, the
    # hot path) or "reference" (the retired per-side lax.cond ladder, kept
    # so the batched path can be pinned bitwise against it).
    server_dispatch: str = "batched"

    def _init_schedule_dispatch(self) -> None:
        """Build the jitted dispatch caches.  Every step function donates
        its state: the buffers alias input to output in XLA instead of
        being copied per dispatch."""
        self._jit_red = jax.jit(self._red_pass, donate_argnums=(1,))
        self._jit_gray: dict[int, Callable] = {}
        if hasattr(self, "_lazy_fill"):
            self._jit_lazy = jax.jit(self._lazy_fill, donate_argnums=(0,))
        if hasattr(self, "_eager_push"):
            self._jit_eager = jax.jit(self._eager_push, donate_argnums=(0,))
        # Fused-chunk caches: decode_chunk per schedule segment (lockstep),
        # server_chunk per (K, dispatch mode) (per-slot traced schedules).
        self._jit_chunk: dict[tuple[int, ...], Callable] = {}
        self._jit_server_chunk: dict[tuple[int, str], Callable] = {}
        # One fused per-step server-tile program: every possible side,
        # mask-selected, in ONE dispatch (the per-step analogue of the
        # batched server chunk; LCSMServer.step drives it).
        self._jit_tiles = jax.jit(self._server_tiles_batched,
                                  donate_argnums=(1,))
        self._jit_import = jax.jit(self._import_slot_rows_impl,
                                   donate_argnums=(0,))
        # Host-visible dispatch accounting: one count per XLA execution
        # launched through the step/chunk surface below (benchmarks report
        # dispatches per token/chunk — the quantity the batched-dispatch
        # refactor exists to shrink).
        self.dispatch_count = 0
        # Flashtrace label memo: side U -> (impl, tau regime) — host-derived
        # once per side (the decision is static per engine config).
        self._obs_side_labels: dict[int, tuple[str, str]] = {}
        # Prefill retrace tracking: jax.jit retraces per padded prompt
        # shape, invisibly to the host — mirror the shape set so the
        # recorder can report prefill program-cache hit/miss/compile.
        self._obs_prefill_shapes: set = set()

    # ------------------------------------------------------------ flashtrace
    # All tracing lives HERE, on the host side of the dispatch boundary: the
    # *_impl bodies below never touch repro.obs (flashcheck FC007), so the
    # cached programs are bitwise independent of whether tracing is on.
    def _obs_gray_labels(self, U: int) -> tuple[str, str]:
        """(impl, tau-regime) labels for a side-U gray tile, memoized."""
        lab = self._obs_side_labels.get(U)
        if lab is None:
            lab = self._obs_side_labels[U] = self._obs_gray_labels_impl(U)
        return lab

    def _obs_gray_labels_impl(self, U: int) -> tuple[str, str]:
        """Default labels; engines override to mirror their real dispatch
        (fused Pallas plan, tau_hybrid direct/fft crossover)."""
        return ("xla", "direct")

    def _obs_record_dispatch(self, rec, kind: str, t0: float, *,
                             cold: bool | None = None,
                             cache_size: int | None = None,
                             gray_sides: dict[int, int] | None = None,
                             span_args: dict | None = None) -> None:
        """Record one host dispatch: span + counters (+ program-cache
        hit/miss, compile instant, jit-cache gauge, per-(side, impl)
        gray-tile and tau-regime counts).  Called only with an active
        recorder; an async dispatch's span is its host launch cost."""
        t1 = _obs.perf_now()
        if gray_sides:
            # The per-side tile mix rides on the span (visible when a span
            # is clicked in Perfetto) and as per-side counter tracks.
            span_args = dict(span_args or {})
            span_args["gray_tiles"] = {
                f"U{U}": n for U, n in sorted(gray_sides.items())}
            for U, n in gray_sides.items():
                rec.add_sample(f"gray_tiles.side_{U}", t1, n)
        rec.add_span(f"engine.{kind}", "engine", t0, t1, span_args)
        rec.inc_counter("flash_dispatch_total", kind=kind)
        if cold is not None:
            rec.inc_counter("flash_program_cache_total", kind=kind,
                            event="miss" if cold else "hit")
            if cold:
                rec.inc_counter("flash_compile_total", kind=kind)
                rec.add_instant(f"compile.{kind}", "engine", t1, span_args)
            if cache_size is not None:
                rec.set_gauge("flash_jit_cache_size", cache_size, kind=kind)
        for U, n in (gray_sides or {}).items():
            impl, regime = self._obs_gray_labels(U)
            rec.inc_counter("flash_gray_tiles_total", n, side=U, impl=impl)
            rec.inc_counter("flash_tau_dispatch_total", n, side=U,
                            regime=regime)

    def _obs_record_prefill(self, rec, kind: str, t0: float,
                            plen: int) -> None:
        """Prefill dispatch record; cold iff this padded prompt length has
        not been traced through this engine before (jit retrace mirror)."""
        key = (kind, int(plen))
        cold = key not in self._obs_prefill_shapes
        self._obs_prefill_shapes.add(key)
        self._obs_record_dispatch(
            rec, kind, t0, cold=cold,
            cache_size=len(self._obs_prefill_shapes),
            span_args={"P": int(plen)})

    def _shard_state(self, state):
        """Pin a sharding on a TRACED state (default: identity).  Mesh-aware
        engines override; called at every state-returning trace's exit."""
        return state

    # ----------------------------------------------------------------- decode
    def generate(
        self,
        state,
        n_tokens: int,
        *,
        origin: int = 0,
        rng: jax.Array | None = None,
        chunk_size: int | None = None,
    ):
        """Lockstep decode of ``n_tokens`` from schedule origin ``origin``.

        Thin host loop over device-resident chunks: each ``decode_chunk``
        fuses up to K schedule steps into one donated XLA computation, so the
        host dispatches (and may sync) once per K tokens instead of several
        times per token.  ``chunk_size=1`` is the historical per-step path
        (one jitted red pass / gray tile per dispatch) — kept as the
        exactness reference: flash and lazy are BITWISE identical chunked
        vs per-step; eager is identical up to rounding (XLA FMA-contracts
        its per-step b += y*rho accumulation when steps fuse).  The input
        ``state`` is donated."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        origin = int(origin)
        K = self.chunk_size if chunk_size is None else chunk_size
        if K <= 1:
            return self._generate_stepwise(state, n_tokens, origin, rng)
        toks = []
        step = 0
        while step < n_tokens:
            k = min(K, n_tokens - step)
            if self.strategy == "flash":
                sides = schedule_segment(step + 1, k, origin=origin,
                                         horizon=self.Lbuf,
                                         last_step=n_tokens)
            else:
                sides = (0,) * k
            state, tk, rng = self.decode_chunk(
                state, origin + step, rng, sides)
            toks.append(tk)
            step += k
        toks = (jnp.concatenate(toks, axis=1) if toks
                else jnp.zeros((self.batch, 0), jnp.int32))
        return state, toks

    def _schedule_step(self, params, state, pv, rng, tile=None, *,
                       jitted: bool):
        """THE schedule step, defined once: rng split -> (lazy fill) -> red
        pass -> (eager push | this step's gray tile).  Every decode path —
        per-step loop, fused lockstep chunk, fused server chunk — drives
        this skeleton; the bit-identity contract between them rests on the
        ordering living in exactly one place.  ``tile`` is a callable
        (state) -> state applying whatever gray tile(s) the step unlocks,
        or None; ``jitted`` picks the per-piece jitted wrappers (per-step
        dispatch) vs the raw methods (tracing inside a fused chunk)."""
        rng, sub = jax.random.split(rng)
        if self.strategy == "lazy":
            state = (self._jit_lazy if jitted else self._lazy_fill)(state, pv)
        state, tok = (self._jit_red if jitted else self._red_pass)(
            params, state, pv, sub)
        if self.strategy == "eager":
            state = (self._jit_eager if jitted else self._eager_push)(state, pv)
        elif tile is not None:
            state = tile(state)
        return state, tok, rng

    def _generate_stepwise(self, state, n_tokens: int, origin: int, rng):
        """Per-step dispatch (the pre-chunking hot loop): one host round-trip
        per red pass and per gray tile."""
        toks = []
        for step in range(n_tokens):
            p = origin + step
            pv = jnp.full((self.batch,), p, jnp.int32)
            tile = None
            if self.strategy == "flash" and step + 1 < n_tokens:
                U = largest_pow2_divisor(step + 1)
                tile = lambda st, p=p, U=U: self._gray_tile_guard(st, p, U)
            state, tok, rng = self._schedule_step(
                self.params, state, pv, rng, tile, jitted=True)
            toks.append(tok)
        toks = (jnp.stack(toks, axis=1) if toks
                else jnp.zeros((self.batch, 0), jnp.int32))
        return state, toks

    # ------------------------------------------------- fused chunked decode
    def _decode_chunk_impl(self, params, state, p0, rng, *,
                           sides: tuple[int, ...]):
        """len(sides) fused schedule steps starting at per-slot positions
        ``p0``.  ``sides[i]`` is the gray-tile side unlocked after red step i
        (0 = no tile: past the last step, or fully past the horizon) — all
        trace-time constants, so the whole chunk is one XLA program with no
        host involvement.  The rng is split exactly as the per-step loop
        splits it, so sampling models see identical keys."""
        toks = []
        for i, U in enumerate(sides):
            pv = p0 + i
            tile = None
            if U:
                tile = lambda st, pv=pv, U=U: self._gray_tile(
                    params, st, pv, jnp.ones((self.batch,), bool), U=U)
            state, tok, rng = self._schedule_step(
                params, state, pv, rng, tile, jitted=False)
            toks.append(tok)
        return state, jnp.stack(toks, axis=1), rng

    def decode_chunk(self, state, p0, rng, sides: Sequence[int]):
        """Run one fused chunk: red pass + block + advance for each step,
        plus the gray tiles ``sides`` prescribes (see tiling.schedule_segment
        for how a segment is derived and why segments make good cache keys).
        ``p0``: position of the first step, scalar or (B,).  Returns
        (state, tokens (B, K), advanced rng); the input state is donated."""
        sides = tuple(int(u) for u in sides)
        fn = self._jit_chunk.get(sides)
        cold = fn is None
        if cold:
            fn = jax.jit(
                functools.partial(self._decode_chunk_impl, sides=sides),
                donate_argnums=(1,))
            self._jit_chunk[sides] = fn
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = fn(self.params, state, as_pos_vec(p0, self.batch), rng)
        if rec is not None:
            tiles: dict[int, int] = {}
            for u in sides:
                if u:
                    tiles[u] = tiles.get(u, 0) + 1
            self._obs_record_dispatch(
                rec, "decode_chunk", t0, cold=cold,
                cache_size=len(self._jit_chunk), gray_sides=tiles,
                span_args={"sides": list(sides), "K": len(sides)})
        return out

    # ------------------------------------------------ server tile dispatch
    def _server_sides(self) -> list[int]:
        """Every tile side a *live* slot can unlock: sides with 2U <= Lbuf
        (its relative step stays < gen_max, so U <= ceil_pow2(gen_max)/2 and
        the buffer holds rho[0..2U-1]).  A blind overshoot step past
        retirement may compute a larger lowbit; no side matches and the
        junk tile is simply skipped."""
        sides = []
        u = 1
        while 2 * u <= self.Lbuf:
            sides.append(u)
            u *= 2
        return sides

    def _side_masks(self, pv, origin, live):
        """Per-slot unlocked tile side + the slots allowed to apply one."""
        rel = pv + 1 - origin          # 1-based schedule step done
        low = rel & (-rel)             # per-slot unlocked tile side
        writable = pv + 1 < self.Lbuf  # full-spill guard (clip
        return low, live & writable    # handles partial spill)

    def _server_tiles_batched(self, params, state, pv, origin, live):
        """BATCHED gather/scatter tile dispatch — the serving hot path.

        Each live slot unlocks exactly one side per step, so the batch
        partitions across the log2(L) possible sides.  For every side U the
        side-U tile body runs UNCONDITIONALLY on the whole batch: it
        gathers each slot's U input rows (per-slot clamped dynamic slices —
        the gather), computes contributions for the gathered sub-batch in
        one call, and scatters them back under the side's slot mask
        (masked scatter-add / select — the scatter).  Compute both
        outcomes, select by mask: NO data-dependent control flow, so no
        ``lax.cond`` predicate has to be computed, replicated across the
        mesh, and branched on before any tile work can start — under
        GSPMD every cond predicate is a cross-device sync, which is
        exactly what made the sharded server anti-scale.

        Identity contract vs the reference ladder: a side whose mask is
        all-False adds a zeroed contribution instead of skipping, which is
        bitwise invisible except that scatter-adding +0.0 maps a stored
        -0.0 to +0.0 (token streams are unaffected; states compare equal
        under IEEE ==).  tests/test_server_dispatch.py pins both."""
        low, ok = self._side_masks(pv, origin, live)
        for U in self._server_sides():
            state = self._gray_tile(params, state, pv, ok & (low == U), U=U)
        return state

    def _server_tiles_reference(self, params, state, pv, origin, live):
        """The RETIRED per-side ``lax.cond`` ladder (PR 2–5 hot loop), kept
        verbatim as the exactness reference for the batched dispatch: for
        each side U a masked ``lax.cond`` applies the side-U tile to
        exactly the slots whose relative step unlocks U this step, and
        skips the computation entirely when no slot does.  Correct, but a
        log2(L) chain of data-dependent branches per step — every
        predicate is a host/mesh sync point — which is why it anti-scaled
        with device count (BENCH_sharded) and was replaced."""
        low, ok = self._side_masks(pv, origin, live)
        for U in self._server_sides():
            m = ok & (low == U)
            state = jax.lax.cond(
                jnp.any(m),
                functools.partial(self._gray_tile, params,
                                  p=pv, mask=m, U=U),
                lambda st: st,
                state)
        return state

    def _server_tiles(self, params, state, pv, origin, live, *,
                      dispatch: str):
        assert dispatch in ("batched", "reference"), dispatch
        fn = (self._server_tiles_batched if dispatch == "batched"
              else self._server_tiles_reference)
        return fn(params, state, pv, origin, live)

    def tiles_step(self, state, p, origin, live):
        """Apply every tile the slots' schedules unlock at per-slot
        positions ``p`` in ONE fused dispatch (batched mask-select over all
        sides) — the per-step server path's replacement for dispatching
        each side group separately.  ``origin``/``live`` as in
        ``server_chunk``.  The input state is donated."""
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = self._jit_tiles(
            self.params, state, as_pos_vec(p, self.batch),
            as_pos_vec(origin, self.batch), jnp.asarray(live, bool))
        if rec is not None:
            self._obs_record_dispatch(
                rec, "tiles_step", t0,
                gray_sides={U: 1 for U in self._server_sides()})
        return out

    def _server_chunk_impl(self, params, state, p0, origin, live, rng, *,
                           K: int, dispatch: str):
        """K fused continuous-batching steps with PER-SLOT schedules.

        Unlike ``_decode_chunk_impl`` the tile side is data-dependent here —
        each slot sits at its own point of its own schedule — so every step
        applies all log2(L) possible sides through the batched
        gather/scatter dispatch (``dispatch="batched"``; the retired cond
        ladder under ``"reference"``).  Slots are stepped blindly for K
        tokens; the host truncates at EOS/max_new after readback —
        overshoot steps only touch the overshooting slot's own rows, which
        the next admission prefill rewrites wholesale.  p0/origin: (B,)
        int32; live: (B,) bool."""
        toks = []
        for i in range(K):
            pv = p0 + i
            tile = None
            if self.strategy == "flash":
                tile = lambda st, pv=pv: self._server_tiles(
                    params, st, pv, origin, live, dispatch=dispatch)
            state, tok, rng = self._schedule_step(
                params, state, pv, rng, tile, jitted=False)
            toks.append(tok)
        return state, jnp.stack(toks, axis=1), rng

    def server_chunk(self, state, p0, origin, live, rng, K: int,
                     dispatch: str | None = None):
        """Fused K-step advance for the continuous-batching server: per-slot
        positions/origins, one dispatch, one deferred token readback.
        ``dispatch`` picks the tile formulation (default: the engine's
        ``server_dispatch``, normally "batched").  Returns (state, tokens
        (B, K), advanced rng); state is donated."""
        dispatch = self.server_dispatch if dispatch is None else dispatch
        fn = self._jit_server_chunk.get((K, dispatch))
        cold = fn is None
        if cold:
            fn = jax.jit(
                functools.partial(self._server_chunk_impl, K=K,
                                  dispatch=dispatch),
                donate_argnums=(1,))
            self._jit_server_chunk[(K, dispatch)] = fn
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = fn(self.params, state, as_pos_vec(p0, self.batch),
                 as_pos_vec(origin, self.batch),
                 jnp.asarray(live, bool), rng)
        if rec is not None:
            # Every step of a flash server chunk applies all possible sides
            # (mask-selected), so the dispatched side-program count is K
            # each.
            tiles = ({U: K for U in self._server_sides()}
                     if self.strategy == "flash" else {})
            self._obs_record_dispatch(
                rec, "server_chunk", t0, cold=cold,
                cache_size=len(self._jit_server_chunk), gray_sides=tiles,
                span_args={"K": K, "dispatch": dispatch})
        return out

    # --------------------------------------------------- prompt-length buckets
    def _bucket_prompt(self, a0_prompt):
        """Right-pad an embedded prompt (B, P, D) with zero rows to the next
        power of two (capped at Lbuf), returning (padded prompt, true P).

        Prompt length is a trace shape, so an unbucketed prefill jit cache
        holds one program per distinct P; bucketing bounds it at
        O(log prompt_max) programs.  The true length rides along as a TRACED
        scalar: the prefill body masks block writes past it and anchors the
        first ``advance`` at plen-1, so padded rows never leak into real
        positions.  Exactness contract: a zero input row must contribute
        nothing — true for LCSM (zero convolution inputs; the FFT size is a
        static function of the padded shape) and for any generic mixer whose
        ``cont`` of an all-zero row is agg-neutral (GLA: k=v=0)."""
        P = a0_prompt.shape[1]
        P2 = min(ceil_pow2(P), self.Lbuf)
        if P2 > P:
            pad = jnp.zeros(
                (a0_prompt.shape[0], P2 - P) + a0_prompt.shape[2:],
                a0_prompt.dtype)
            a0_prompt = jnp.concatenate([a0_prompt, pad], axis=1)
        return a0_prompt, P

    # ------------------------------------------------ slot-state export/import
    # The entire inference state of a slot is its fixed-size buffer rows (a
    # key LCSM/generic property: no growing KV cache), so a prompt's
    # post-prefill state can be snapshotted and later restored into any slot
    # of any same-shaped engine by a row copy — the mechanism behind the
    # serving frontend's prefix-state cache (serving/frontend/prefix_cache).

    def export_slot_rows(self, state, slot):
        """Copy slot ``slot``'s full buffer rows out of ``state`` as a
        batch-1 state pytree.  The returned leaves are FRESH buffers (a
        gather, not a view), so they stay valid after the engine donates
        and overwrites ``state`` in subsequent steps — safe to hold in a
        host-side cache.  The input state is NOT donated."""
        i = jnp.asarray(slot, jnp.int32)
        return jax.tree.map(
            lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, i, 1, axis=0),
            state)

    def import_slot_rows(self, state, slot, rows):
        """Write a previously exported batch-1 ``rows`` pytree into row
        ``slot`` of the batched state (one dynamic_update_slice per leaf —
        no other slot is disturbed; slot reuse needs no reset because every
        row is overwritten).  Restoring rows exported right after a
        ``prefill_slot`` reproduces that admission BITWISE: the restored
        slot is indistinguishable from one that just ran the prefill.
        The input state is donated.  Returns the new state."""
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = self._jit_import(state, jnp.asarray(slot, jnp.int32), rows)
        if rec is not None:
            self._obs_record_dispatch(rec, "import_slot_rows", t0,
                                      span_args={"slot": int(slot)})
        return out

    def _import_slot_rows_impl(self, state, slot, rows):
        return self._shard_state(jax.tree.map(
            lambda big, one: write_slot_rows(big, one, slot), state, rows))

    def _gray_tile_guard(self, state, p: int, U: int):
        if p + 1 >= self.Lbuf:  # no output position fits in the buffer: skip.
            return state        # (Tiles that only PARTIALLY spill are clipped
        return self.gray_step(state, p, None, U)  # inside _gray_tile.)

    # ------------------------------------------- continuous-serving step API
    # All step functions DONATE the input state (buffers alias in place);
    # callers must thread the returned state and never reuse the argument.
    def red_step(self, state, p, rng):
        """Finalize per-slot positions p ((B,) or scalar) and sample every
        slot; returns (state, tokens (B,))."""
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = self._jit_red(self.params, state, as_pos_vec(p, self.batch), rng)
        if rec is not None:
            self._obs_record_dispatch(rec, "red_step", t0)
        return out

    def lazy_step(self, state, p):
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = self._jit_lazy(state, as_pos_vec(p, self.batch))
        if rec is not None:
            self._obs_record_dispatch(rec, "lazy_step", t0)
        return out

    def eager_step(self, state, p):
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = self._jit_eager(state, as_pos_vec(p, self.batch))
        if rec is not None:
            self._obs_record_dispatch(rec, "eager_step", t0)
        return out

    def gray_step(self, state, p, mask, U: int):
        """Apply the side-U gray tile at per-slot positions p to the slots
        selected by ``mask`` ((B,) bool; None = all).  Jitted once per tile
        side — slot index and positions stay traced."""
        fn = self._jit_gray.get(U)
        cold = fn is None
        if cold:
            fn = jax.jit(functools.partial(self._gray_tile, U=U),
                         donate_argnums=(1,))
            self._jit_gray[U] = fn
        mask = (jnp.ones((self.batch,), bool) if mask is None
                else jnp.asarray(mask))
        self.dispatch_count += 1
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = fn(self.params, state, as_pos_vec(p, self.batch), mask)
        if rec is not None:
            self._obs_record_dispatch(
                rec, "gray_step", t0, cold=cold,
                cache_size=len(self._jit_gray),
                gray_sides={U: 1}, span_args={"U": U})
        return out
