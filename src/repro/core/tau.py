"""τ — range-to-range contribution primitives (paper Lemma 1 + Appendix C).

``tau(y[l..r] , rho) -> contributions to z[l'..r']``.  Algorithm 2 only ever
needs the square case ``l' = r+1, r' = r+U`` with ``U = r-l+1``; the general
Lemma-1 form is provided for tests and for the generic framework.

Conventions
-----------
* channel-last arrays: ``y_tile`` has shape ``(..., U, C)``; filters are
  ``(..., 2U, C)`` slices ``rho[0 .. 2U-1]`` (the ``rho_0`` entry is present
  but mathematically unused by the tile — the red cell owns it).
* output ``(..., U, C)``: ``out[t] = sum_s y[s] * rho[U + t - s]`` for
  ``t, s in [0, U)`` — i.e. the contribution of the U inputs ending at step
  ``i`` to the U outputs starting at ``i+1``.

Implementations (paper §5.2): ``direct`` (quadratic in U, MXU-friendly),
``fft`` (order-2U circular convolution — Appendix C's half-length trick),
``pallas`` (the direct form as an explicit-VMEM TPU kernel), and ``hybrid``
(static per-U dispatch, the TPU analogue of the paper's measured Pareto
frontier).
"""

from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def _band_index(U: int) -> jnp.ndarray:
    """(U, U) gather index: idx[t, s] = U + t - s  (values in [1, 2U-1])."""
    t = jnp.arange(U)
    return U + t[:, None] - t[None, :]


def tau_direct(y_tile: jnp.ndarray, rho2u: jnp.ndarray) -> jnp.ndarray:
    """Direct (quadratic-in-U) evaluation. O(U^2 C) multiply-adds.

    y_tile: (..., U, C); rho2u: broadcast-compatible (..., 2U, C).
    """
    U = y_tile.shape[-2]
    if rho2u.shape[-2] != 2 * U:
        raise ValueError(f"rho2u must have length 2U={2*U}, got {rho2u.shape[-2]}")
    rmat = jnp.take(rho2u, _band_index(U), axis=-2)  # (..., U, U, C)
    return jnp.einsum(
        "...tsc,...sc->...tc", rmat, y_tile, preferred_element_type=_F32
    ).astype(y_tile.dtype)


def rho_dft(rho2u: jnp.ndarray) -> jnp.ndarray:
    """Precompute the filter DFT for a tile size (Appendix C: 3 -> 2 DFTs)."""
    n = rho2u.shape[-2]
    return jnp.fft.rfft(rho2u.astype(_F32), n=n, axis=-2)


def tau_fft(
    y_tile: jnp.ndarray,
    rho2u: jnp.ndarray | None = None,
    rho_f: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """FFT evaluation via an order-2U *circular* convolution (Appendix C).

    The linear convolution of the U inputs with rho[0..2U-1] has length 3U-1;
    its cyclic fold (length 2U) wraps outputs [2U, 3U-2] onto [0, U-2], never
    touching the U outputs of interest [U, 2U-1] — so a 2U FFT suffices
    (a 2x saving over the canonical 4U zero-padded transform).
    """
    U = y_tile.shape[-2]
    n = 2 * U
    if rho_f is None:
        if rho2u is None:
            raise ValueError("need rho2u or its precomputed DFT")
        rho_f = rho_dft(rho2u)
    y_f = jnp.fft.rfft(y_tile.astype(_F32), n=n, axis=-2)
    circ = jnp.fft.irfft(y_f * rho_f, n=n, axis=-2)
    return circ[..., U : 2 * U, :].astype(y_tile.dtype)


def make_rho_dfts(rho: jnp.ndarray, max_tile: int) -> Mapping[int, jnp.ndarray]:
    """Precompute {U: DFT(rho[0..2U-1], n=2U)} for U = 1, 2, 4, ..., max_tile.

    rho: (..., L, C) with L >= 2*max_tile (Algorithm 2 only needs prefixes).
    This is the paper's §5.4 engineering contribution #1: log2(L)-1 cached
    filter transforms, amortized over 2^(P-1-q) tiles each.
    """
    dfts: dict[int, jnp.ndarray] = {}
    U = 1
    while U <= max_tile:
        dfts[U] = rho_dft(rho[..., : 2 * U, :])
        U *= 2
    return dfts


def make_rho_prefixes(rho: jnp.ndarray, max_tile: int) -> Mapping[int, jnp.ndarray]:
    """Precompute {U: rho[0..2U-1]} for U = 1, 2, 4, ..., max_tile — the
    time-domain companion of :func:`make_rho_dfts`.

    The direct/Pallas τ kernels need the time-domain filter; a caller that
    cached only the DFTs forces ``tau_hybrid`` to reconstruct it with an
    inverse FFT inside every traced program — one irfft per small-U tile
    per step in the Alg.-2 hot loop.  Engines cache these prefixes
    alongside the DFTs so no cached decode/server program contains that
    reconstruction (tests/test_decode_chunk.py pins the fft-free jaxpr).
    """
    pres: dict[int, jnp.ndarray] = {}
    U = 1
    while U <= max_tile:
        pres[U] = rho[..., : 2 * U, :]
        U *= 2
    return pres


def tau_hybrid(
    y_tile: jnp.ndarray,
    rho2u: jnp.ndarray | None = None,
    rho_f: jnp.ndarray | None = None,
    *,
    direct_max: int = 32,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Static per-tile-size dispatch (paper §5.3 'Hybrid').

    Tile sizes are powers of two known at trace time, so the branch is free.
    ``direct_max`` is the measured crossover (benchmarks/bench_tau.py).
    """
    U = y_tile.shape[-2]
    if U <= direct_max:
        if rho2u is None:
            # Only the precomputed DFT was passed (the Alg.-2 hot loop caches
            # exactly that).  The direct kernels need the time-domain filter;
            # recover it from the order-2U DFT — rfft is information-preserving
            # for real input, so irfft is an exact inverse up to rounding.
            if rho_f is None:
                raise ValueError("tau_hybrid needs rho2u or its DFT rho_f")
            rho2u = jnp.fft.irfft(rho_f, n=2 * U, axis=-2)
        if use_pallas:
            from repro.kernels import ops as kops

            return kops.tile_conv(y_tile, rho2u)
        return tau_direct(y_tile, rho2u)
    return tau_fft(y_tile, rho2u=rho2u, rho_f=rho_f)


def tau_ranges(
    y: jnp.ndarray, rho: jnp.ndarray, l: int, r: int, lp: int, rp: int
) -> jnp.ndarray:
    """General Lemma-1 τ: contributions of y[l..r] to z[lp..rp] (1-based,
    inclusive; requires r <= lp).  Direct evaluation — test/reference use.

    y: (..., L, C), rho: (..., L, C).  Returns (..., rp-lp+1, C).
    """
    if not (1 <= l <= r <= lp <= rp):
        raise ValueError(f"bad ranges ({l},{r},{lp},{rp})")
    yseg = y[..., l - 1 : r, :]  # (.., L1, C)
    ts = jnp.arange(lp, rp + 1)[:, None]  # output positions (1-based)
    is_ = jnp.arange(l, r + 1)[None, :]  # input positions
    idx = ts - is_  # (L2, L1) rho lags, all >= lp - r >= 0
    rmat = jnp.take(rho, idx, axis=-2)  # (..., L2, L1, C)
    return jnp.einsum(
        "...tsc,...sc->...tc", rmat, yseg, preferred_element_type=_F32
    ).astype(y.dtype)


def tau_offsets(
    y_seg: jnp.ndarray, rho: jnp.ndarray, out_offsets: jnp.ndarray
) -> jnp.ndarray:
    """General Lemma-1 τ for translation-invariant filters: contributions
    of the U inputs ending at some position i to the outputs at positions
    ``i + off`` for each ``off`` in ``out_offsets`` (all >= 1, possibly
    traced/non-contiguous).  y_seg: (..., U, C); rho: (L, C) with
    L > max(off) + U - 1.  Returns (..., n_off, C).  Direct evaluation —
    the generic engine's fallback when offsets aren't a recognizable
    square/rectangular pattern."""
    U = y_seg.shape[-2]
    idx = out_offsets[:, None] + (U - 1) - jnp.arange(U)[None, :]
    rmat = jnp.take(rho, idx, axis=-2)  # (n_off, U, C)
    return jnp.einsum(
        "...tsc,...sc->...tc", rmat, y_seg, preferred_element_type=_F32
    ).astype(y_seg.dtype)


@functools.partial(jax.jit, static_argnames=("out_len",))
def conv_causal_fft(y: jnp.ndarray, rho: jnp.ndarray, out_len: int | None = None) -> jnp.ndarray:
    """Static (training / prefill) causal convolution via one big FFT:
    z[t] = sum_{k<=t} y[k] * rho[t-k].   y: (..., T, C), rho: (..., >=T, C).
    """
    T = y.shape[-2]
    out_len = T if out_len is None else out_len
    n = 1
    while n < T + out_len:
        n *= 2
    y_f = jnp.fft.rfft(y.astype(_F32), n=n, axis=-2)
    r_f = jnp.fft.rfft(rho[..., :out_len, :].astype(_F32), n=n, axis=-2)
    z = jnp.fft.irfft(y_f * r_f, n=n, axis=-2)
    return z[..., :out_len, :].astype(y.dtype)


def conv_causal_direct(y: jnp.ndarray, rho: jnp.ndarray) -> jnp.ndarray:
    """O(T^2) oracle for conv_causal_fft."""
    T = y.shape[-2]
    ts = jnp.arange(T)[:, None]
    is_ = jnp.arange(T)[None, :]
    lag = ts - is_
    mask = lag >= 0
    rmat = jnp.take(rho, jnp.where(mask, lag, 0), axis=-2)
    rmat = jnp.where(mask[..., None], rmat, 0)
    return jnp.einsum(
        "...tsc,...sc->...tc", rmat, y, preferred_element_type=_F32
    ).astype(y.dtype)
