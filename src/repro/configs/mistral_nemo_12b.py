"""mistral-nemo-12b — [hf:mistralai/Mistral-Nemo-Base-2407]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, 128k ctx."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    head_dim=128,  # Nemo uses head_dim 128 (not d_model/n_heads=160)
    d_ff=14336, vocab=131072, rope_theta=1e6,
    long_ctx_mode="window",
))
