"""Config registry: import every arch module so `register` runs."""
from repro.configs.base import ModelConfig, LayerDef, Stack, get_config, list_configs  # noqa: F401

from repro.configs import (  # noqa: F401
    phi3_5_moe_42b, mistral_nemo_12b, internlm2_20b, deepseek_coder_33b,
    whisper_tiny, deepseek_v3_671b, qwen2_5_3b, falcon_mamba_7b,
    qwen2_vl_72b, jamba_1_5_large, hyena, gla,
)

ASSIGNED = (
    "phi3.5-moe-42b-a6.6b", "mistral-nemo-12b", "internlm2-20b",
    "deepseek-coder-33b", "whisper-tiny", "deepseek-v3-671b", "qwen2.5-3b",
    "falcon-mamba-7b", "qwen2-vl-72b", "jamba-1.5-large-398b",
)
