"""falcon-mamba-7b — [arXiv:2410.05355]
64L d_model=4096 attn-free mamba-1 blocks, vocab=65024, ssm_state=16."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    ssm_state=16, conv_k=4, d_inner=8192,
    train_microbatch=8,
    long_ctx_mode="native",
))
