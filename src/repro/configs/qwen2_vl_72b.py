"""qwen2-vl-72b — [arXiv:2409.12191]
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064; M-RoPE; dynamic-
resolution ViT frontend is a STUB — input_specs() provides patch embeddings
+ 3-stream (t,h,w) position ids."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
    m_rope=True, m_rope_sections=(16, 24, 24),
    train_microbatch=2,
    long_ctx_mode="window",
))
