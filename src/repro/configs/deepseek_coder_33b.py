"""deepseek-coder-33b — [arXiv:2401.14196]
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256, llama-arch."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab=32256, rope_theta=1e5,
    train_microbatch=2,
    long_ctx_mode="window",
))
