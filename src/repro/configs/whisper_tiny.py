"""whisper-tiny — [arXiv:2212.04356]
4L (decoder) d_model=384 6H d_ff=1536 vocab=51865; enc-dec; conv frontend is
a STUB per the assignment — input_specs() provides 1500 precomputed mel-frame
embeddings of shape (B, 1500, 384)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, norm="ln",
    enc_layers=4, enc_positions=1500,
    long_ctx_mode="skip",  # enc-dec, 448-token decoder by construction
))
