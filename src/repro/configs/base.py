"""Architecture config schema + registry.

Every assigned architecture is a ``ModelConfig`` (exact numbers from the
assignment, source cited in the per-arch module).  ``smoke()`` returns the
reduced same-family variant used by CPU tests (≤2 layers, d_model ≤ 512,
≤4 experts).  ``to_hyena()`` converts any dense config into its LCSM twin —
the vehicle for exercising the paper's technique at assigned-arch scale.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Literal, Sequence

MixerKind = Literal["attn", "mla", "mamba", "hyena", "gla", "attn_cross"]
FFNKind = Literal["dense", "moe", "none"]


@dataclass(frozen=True)
class LayerDef:
    mixer: MixerKind
    ffn: FFNKind


@dataclass(frozen=True)
class Stack:
    """``repeat`` copies of ``pattern`` — lowered as one jax.lax.scan over
    the repeat axis (params stacked), keeping HLO size O(len(pattern))."""

    pattern: tuple[LayerDef, ...]
    repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm", "lcsm",
                    "gla"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int | None = None          # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: Literal["rms", "ln"] = "rms"
    tie_embeddings: bool = False

    # sliding window: None = full attention; int = window size. For the
    # assigned long_500k shape, dense archs run the windowed variant.
    sliding_window: int | None = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int | None = None
    n_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 512
    first_k_dense: int = 0               # deepseek-v3: first 3 layers dense
    moe_every: int = 1                   # jamba: MoE every 2nd layer

    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora: int = 1536
    kv_lora: int = 512
    rope_dim: int = 64
    v_head_dim: int | None = None

    # SSM (mamba-1)
    ssm_state: int = 16
    d_inner: int | None = None
    conv_k: int = 4

    # hybrid (jamba): attention every `attn_every` layers within a period
    attn_every: int = 0                  # 0 = not hybrid; jamba: 8

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_positions: int = 0               # whisper-tiny: 1500 mel frames

    # deepseek-v3 multi-token prediction (depth-1, training loss only)
    mtp: bool = False

    # VLM (qwen2-vl)
    m_rope: bool = False
    m_rope_sections: tuple[int, int, int] = (16, 24, 24)

    # LCSM / Hyena
    hyena_order: int = 3                 # order-3: 2 long-conv mixers/operator
    filter_pos_dim: int = 16             # implicit-filter positional features
    filter_mlp_width: int = 64
    short_conv_k: int = 4
    # filter sharing (multi-head Hyena, Massaroli et al.): number of filter
    # groups; 0 = one filter per channel (Poli et al. default).
    hyena_filter_groups: int = 0
    filter_decay_fast: float = 0.3       # per-channel decay window range
    filter_decay_slow: float = 1e-3

    # GLA ("and Beyond" generic-mixer family): per-layer gated linear
    # attention with key/value dims dk/dv (0 = d_model) and decay λ.
    gla_dk: int = 0
    gla_dv: int = 0
    gla_lam: float = 0.98

    # gradient-accumulation microbatches for train_4k (memory/throughput trade)
    train_microbatch: int = 1

    # which decode path long_500k uses (set per arch; see DESIGN §5)
    long_ctx_mode: Literal["native", "window", "skip"] = "window"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.moe_d_ff is None and self.n_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -------------------------------------------------------------- stacks
    def stacks(self) -> tuple[Stack, ...]:
        if self.family == "lcsm":
            n_ops = self.n_layers // (self.hyena_order - 1)
            return (Stack((LayerDef("hyena", "dense"),), n_ops),)
        if self.family == "gla":
            return (Stack((LayerDef("gla", "dense"),), self.n_layers),)
        if self.family == "ssm":
            return (Stack((LayerDef("mamba", "none"),), self.n_layers),)
        if self.family == "hybrid":
            period: list[LayerDef] = []
            for i in range(self.attn_every):
                mixer: MixerKind = "attn" if i == self.attn_every // 2 else "mamba"
                ffn: FFNKind = "moe" if (i % self.moe_every == self.moe_every - 1) else "dense"
                period.append(LayerDef(mixer, ffn))
            return (Stack(tuple(period), self.n_layers // self.attn_every),)
        mixer = "mla" if self.use_mla else "attn"
        if self.n_experts:
            head = ()
            if self.first_k_dense:
                head = (Stack((LayerDef(mixer, "dense"),), self.first_k_dense),)
            return head + (
                Stack((LayerDef(mixer, "moe"),), self.n_layers - self.first_k_dense),
            )
        if self.family == "audio":
            # decoder stack (self-attn + cross-attn handled inside layer)
            return (Stack((LayerDef("attn_cross", "dense"),), self.n_layers),)
        return (Stack((LayerDef(mixer, "dense"),), self.n_layers),)

    # --------------------------------------------------------- derivations
    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU tests (per the assignment:
        ≤2 layers, d_model ≤ 512, ≤4 experts)."""
        d = min(self.d_model, 64)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        changes = dict(
            name=self.name + "-smoke",
            n_layers=max(2, self.attn_every) if self.attn_every else 2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d // heads,
            d_ff=min(self.d_ff, 128) if self.d_ff else 0,
            vocab=min(self.vocab, 256),
            enc_layers=min(self.enc_layers, 2),
            enc_positions=min(self.enc_positions, 16),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
        )
        if self.n_experts:
            changes.update(n_experts=4, top_k=min(self.top_k, 2),
                           moe_capacity_factor=8.0,
                           moe_d_ff=min(self.moe_d_ff or 128, 128),
                           first_k_dense=min(self.first_k_dense, 1))
        if self.use_mla:
            changes.update(q_lora=32, kv_lora=16, rope_dim=8, head_dim=16,
                           v_head_dim=16)
        if self.m_rope:
            hd2 = (d // heads) // 2
            changes.update(m_rope_sections=(hd2 - 2 * (hd2 // 3),) + (hd2 // 3,) * 2)
        if self.family in ("ssm", "hybrid"):
            changes.update(ssm_state=8, conv_k=4, d_inner=2 * d)
        if self.family == "lcsm":
            changes.update(filter_pos_dim=8, filter_mlp_width=16)
        if self.family == "gla":
            changes.update(gla_dk=min(self.gla_dk or 16, 16),
                           gla_dv=min(self.gla_dv or d, d))
        return dataclasses.replace(self, **changes)

    def to_hyena(self) -> "ModelConfig":
        """LCSM twin of a dense config: attention → Hyena operators of the
        same d_model / depth (DESIGN §4 — how the paper's technique is
        exercised at assigned-arch scale)."""
        assert self.family in ("dense", "moe", "vlm")
        return dataclasses.replace(
            self,
            name=self.name + "-hyena",
            family="lcsm",
            n_layers=2 * (self.n_layers // 2),
            sliding_window=None,
            n_experts=0, top_k=0, first_k_dense=0,
            use_mla=False, m_rope=False,
            long_ctx_mode="native",
        )


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401 — populate registry

    if name.endswith("-hyena") and name not in _REGISTRY:
        return get_config(name[: -len("-hyena")]).to_hyena()
    if name.endswith("-smoke") and name not in _REGISTRY:
        return get_config(name[: -len("-smoke")]).smoke()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Sequence[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
