"""hyena — the paper's own architecture [Poli et al. 2023, arXiv:2302.10866],
at the paper's experimental scale (§5: M=18 mixers = 9 order-3 operators,
D=768). This is the faithful-reproduction config; *-hyena twins of the dense
assigned archs scale the same family up (configs/base.to_hyena)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hyena", family="lcsm",
    n_layers=18,            # mixers; 9 operators (order 3 => 2 mixers each)
    d_model=768, n_heads=1, n_kv_heads=1,
    d_ff=3072, vocab=50257,
    hyena_order=3, short_conv_k=4,
    long_ctx_mode="native",
))
