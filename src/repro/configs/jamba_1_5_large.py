"""jamba-1.5-large-398b — [arXiv:2403.19887]
72L d_model=8192 64H (GQA kv=8) d_ff=24576; Mamba+attn 1:7 interleave
(period 8, attention at slot 4), MoE 16e top-2 every other layer."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, moe_d_ff=24576, moe_every=2,
    attn_every=8,
    train_microbatch=8,
    ssm_state=16, conv_k=4, d_inner=16384,
    long_ctx_mode="native",
))
