"""gla — the paper's "and Beyond" instance served for real: a gated
linear-attention LM in the style of "Transformers are RNNs"
[Katharopoulos et al. 2020, arXiv:2006.16236] with a learned-free decay
gate (Laughing Hyena / RetNet-style λ), sized like a small GPT-2.  Decode
runs through the GENERIC Flash-Inference engine (core/generic.py,
Algorithm 4) rather than the LCSM engine — the point of the config is
that make_server drives a second mixer family behind the same surface."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gla", family="gla",
    n_layers=12,
    d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=50257,
    gla_dk=64, gla_dv=512, gla_lam=0.98,
    long_ctx_mode="native",
))
