"""deepseek-v3-671b — [arXiv:2412.19437]
61L d_model=7168 128H d_ff=2048(moe) vocab=129280; MLA; 1 shared + 256 routed
top-8; first 3 layers dense (d_ff 18432); MTP depth-1 (training loss only)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    head_dim=128, v_head_dim=128,
    d_ff=18432,            # dense layers
    moe_d_ff=2048,         # per-expert width (assignment: d_ff=2048)
    vocab=129280,
    n_experts=256, top_k=8, n_shared_experts=1, first_k_dense=3,
    use_mla=True, mtp=True,
    train_microbatch=4, q_lora=1536, kv_lora=512, rope_dim=64,
    long_ctx_mode="window",
))
