"""Serving launcher: batched autoregressive generation on the host mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --n-requests 6 --slots 2 --max-new 8

LCSM archs route through the Flash Inference engine (LCSMServer); all
others use the continuous-batching ServingEngine with per-family caches.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import LM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    rng = np.random.RandomState(0)
    t0 = time.perf_counter()
    if cfg.family == "lcsm":
        from repro.models.hyena import HyenaLCSM
        from repro.serving import LCSMServer

        params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
        srv = LCSMServer(cfg, params, batch=args.slots, gen_max=args.max_new,
                         prompt_max=args.prompt_len)
        prompts = rng.randint(0, cfg.vocab, (args.slots, args.prompt_len)).astype(np.int32)
        toks = srv.generate(prompts, args.max_new)
        for i, row in enumerate(toks):
            print(f"req {i}: {row.tolist()}")
    else:
        import jax.numpy as jnp

        from repro.serving import Request, ServingEngine

        model = LM(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, n_slots=args.slots,
                            max_seq=args.max_seq, cache_dtype=jnp.float32)
        for i in range(args.n_requests):
            eng.submit(Request(
                uid=i,
                prompt=rng.randint(0, cfg.vocab, (args.prompt_len,)).astype(np.int32),
                max_new=args.max_new))
        done = eng.run()
        for r in sorted(done, key=lambda r: r.uid):
            print(f"req {r.uid}: {r.out}")
    dt = time.perf_counter() - t0
    n_tok = args.n_requests * args.max_new if cfg.family != "lcsm" \
        else args.slots * args.max_new
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
