"""Serving launcher: continuously batched autoregressive generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --n-requests 6 --slots 2 --max-new 8
    PYTHONPATH=src python -m repro.launch.serve --arch gla --smoke \
        --slots 3 --chunk 4

All backend families go through ``repro.serving.make_server``: LCSM archs
get the slot-based Flash-Inference LCSMServer (per-slot tile schedules),
GLA archs the GenericServer (same schedules through the §4 generic
engine), all others the ServingEngine with per-family caches.  Same
admission loop either way: submit -> run -> slots refill as requests
retire.

Traffic mode (``--traffic``) serves the same workload through the
frontend scheduler instead of submit-all-upfront: seeded Poisson-style
arrivals (``--arrival-rate``), pluggable admission policy (``--policy``),
bounded queue (``--queue-limit``), streamed token delivery, and a latency
telemetry snapshot.  ``--prefix-cache [BYTES]`` adds the content-addressed
prefix-state cache (LCSM/GLA only): requests repeating a system prompt
skip prefill via a slot-row restore.  ``--hit-frac`` controls how much of
the generated traffic reuses shared prompts:

    PYTHONPATH=src python -m repro.launch.serve --arch hyena --smoke \
        --traffic --n-requests 12 --slots 3 --prefix-cache --hit-frac 0.6

Multi-device: ``--mesh-data N [--mesh-model M]`` builds an (N, M) serving
mesh (launch/mesh.make_serving_mesh) and shards slots over 'data' /
channels over 'model'.  On a CPU host, force devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.serve --arch hyena --smoke \
        --slots 4 --mesh-data 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.lm import LM
from repro.serving import Request, make_server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--strategy", default="flash",
                    choices=["flash", "lazy", "eager"],
                    help="LCSM mixer strategy (ignored for other families)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="fused decode chunk size K (LCSM/GLA backends); "
                         "default: per-step")
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="shard slots over a 'data' mesh axis of this size")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="shard channels over a 'model' mesh axis")
    ap.add_argument("--replicas", type=int, default=0,
                    help="run N independent per-device engine replicas with "
                         "frontend request routing (data parallelism, no "
                         "collectives); mutually exclusive with --mesh-*")
    ap.add_argument("--traffic", action="store_true",
                    help="serve via the frontend scheduler (timed arrivals, "
                         "streaming, telemetry) instead of submit-then-run")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="traffic mode: mean arrivals per decode step")
    ap.add_argument("--policy", default="fcfs", choices=["fcfs", "spf"],
                    help="traffic mode: admission policy")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="traffic mode: frontend queue bound (backpressure)")
    ap.add_argument("--prefix-cache", nargs="?", type=int, const=-1,
                    default=None, metavar="BYTES",
                    help="traffic mode: enable the prefix-state cache, "
                         "optionally with an LRU byte budget")
    ap.add_argument("--hit-frac", type=float, default=0.5,
                    help="traffic mode: share of arrivals reusing one of "
                         "two shared system prompts")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="enable flashtrace and write a Chrome/Perfetto "
                         "trace.json here at exit (open at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="enable flashtrace and write a Prometheus "
                         "text-exposition metrics snapshot here at exit")
    args = ap.parse_args()

    # Flashtrace rides fully host-side (README "Observability"): enabling
    # it changes no jitted program and no emitted token.
    rec = None
    if args.trace_out or args.metrics_out:
        from repro import obs
        rec = obs.enable_tracing()

    def export_obs():
        if rec is None:
            return
        from repro import obs
        if args.trace_out:
            obs.write_trace_json(rec, args.trace_out)
            print(f"flashtrace: wrote {args.trace_out} "
                  "(open at https://ui.perfetto.dev)")
        if args.metrics_out:
            obs.write_metrics_text(rec, args.metrics_out)
            print(f"flashtrace: wrote {args.metrics_out}")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    if args.replicas > 1 and (args.mesh_data or args.mesh_model > 1):
        raise ValueError(
            f"--replicas {args.replicas} cannot be combined with "
            f"--mesh-data/--mesh-model (got data={args.mesh_data}, "
            f"model={args.mesh_model}): replica mode runs N independent "
            "single-device engines — there is no mesh to shard over.  "
            "Pick ONE multi-device layout: --replicas N (frontend data "
            "parallelism) or --mesh-data/--mesh-model (one sharded engine).")
    if args.replicas > len(jax.devices()):
        raise ValueError(
            f"--replicas {args.replicas} exceeds the {len(jax.devices())} "
            "visible device(s); on a CPU host force more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")

    mesh = None
    if args.mesh_data or args.mesh_model > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(data=max(args.mesh_data, 1),
                                 model=args.mesh_model)
        print(f"serving mesh: {dict(mesh.shape)} over "
              f"{mesh.devices.size} {jax.devices()[0].platform} device(s)")

    if cfg.family == "lcsm":
        from repro.models.hyena import HyenaLCSM

        params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
        extra = {"strategy": args.strategy}
    elif cfg.family == "gla":
        from repro.models.gla import GLALM

        params = GLALM(cfg).init(jax.random.PRNGKey(0))
        extra = {}
    else:
        params = LM(cfg).init(jax.random.PRNGKey(0))
        extra = {"cache_dtype": jnp.float32}
    srv = make_server(cfg, params, n_slots=args.slots, max_seq=args.max_seq,
                      prompt_max=args.prompt_len, gen_max=args.max_new,
                      mesh=mesh,
                      replicas=args.replicas if args.replicas > 1 else None,
                      **extra)
    if args.replicas > 1:
        print(f"{args.replicas} engine replicas over "
              f"{jax.devices()[0].platform} devices "
              f"({args.slots} slots each)")

    if args.traffic:
        import json

        from repro.serving.frontend import make_frontend, poisson_trace

        budget = (args.prefix_cache if args.prefix_cache is not None
                  and args.prefix_cache >= 0 else None)
        sched = make_frontend(srv, policy=args.policy,
                              queue_limit=args.queue_limit,
                              prefix_cache=args.prefix_cache is not None,
                              prefix_cache_bytes=budget, chunk=args.chunk)
        cache = sched.cache
        trace = poisson_trace(cfg.vocab, args.n_requests,
                              rate=args.arrival_rate,
                              prompt_max=args.prompt_len,
                              gen_max=args.max_new,
                              hit_frac=args.hit_frac)
        for ev in sched.serve(trace):  # streaming consumption
            print(f"  t={ev.step:6.1f} req {ev.uid} tok[{ev.index}]="
                  f"{ev.token}{'  <done>' if ev.done else ''}")
        if hasattr(sched, "metrics"):
            snap = sched.metrics.snapshot()
            snap.pop("per_request")
        else:  # replica-routing scheduler: merged per-replica snapshots
            snap = sched.metrics_snapshot()
        if cache is not None:
            snap["prefix_cache"] = cache.stats()
        print(json.dumps(snap, indent=1, default=float))
        export_obs()
        return

    rng = np.random.RandomState(0)
    t0 = time.perf_counter()
    for i in range(args.n_requests):
        srv.submit(Request(
            uid=i,
            prompt=rng.randint(0, cfg.vocab, (args.prompt_len,)).astype(np.int32),
            max_new=args.max_new))
    done = srv.run(chunk=args.chunk)
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: {r.out}")
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
    export_obs()


if __name__ == "__main__":
    main()
