"""Dry-run case builder: (arch × input-shape) → step fn + ShapeDtypeStruct
inputs + shardings.

Every case captures one jit-able program:
  train_4k    → train_step (fwd + bwd + AdamW)
  prefill_32k → prefill (full-seq forward, emits decode caches)
  decode_32k  → serve_step (ONE token against a 32k cache)
  long_500k   → serve_step with a 524288-token context — sub-quadratic
                paths only: SSM/hybrid native state decode; dense/MoE/VLM
                run the sliding-window(8192) variant; LCSM runs the Flash
                Inference red step; whisper skipped (enc-dec, 448-token
                decoder by construction).

No real arrays are built for the full configs: params come from
``jax.eval_shape(model.init, ...)``, inputs from ShapeDtypeStructs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import make_batch_specs
from repro.launch import lcsm_steps, sharding as sh
from repro.models.lm import LM
from repro.optim import AdamWConfig, adamw_init
from repro.train_loop import make_train_step

SHAPES: dict[str, dict] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

LONG_WINDOW = 8192  # sliding-window size for dense archs at 500k (DESIGN §5)


@dataclass
class Case:
    arch: str
    shape: str
    step_fn: Callable
    args: tuple                 # ShapeDtypeStructs (positional)
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()
    note: str = ""


@dataclass
class Skip:
    arch: str
    shape: str
    reason: str


def _params_sds(model: LM):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def _to_inference_dtype(sds_tree):
    """Serving runs bf16 weights (training keeps f32 masters)."""
    def cast(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
        return s
    return jax.tree.map(cast, sds_tree)


def build_case(cfg: ModelConfig, shape_name: str, mesh) -> Case | Skip:
    info = SHAPES[shape_name]
    S, B, kind = info["seq_len"], info["global_batch"], info["kind"]
    dp = sh.data_axes(mesh)
    n_dp = 1
    for ax in (dp if isinstance(dp, tuple) else (dp,)):
        n_dp *= mesh.shape[ax]

    # ----------------------------------------------------------- skip rules
    if shape_name == "long_500k":
        if cfg.long_ctx_mode == "skip":
            return Skip(cfg.name, shape_name,
                        "enc-dec decoder is 448 tokens by construction "
                        "(noted in DESIGN §5)")
    if B % n_dp and B > 1:
        return Skip(cfg.name, shape_name, f"batch {B} not divisible by data axis {n_dp}")

    if cfg.family == "lcsm":
        return _lcsm_case(cfg, shape_name, mesh)

    model = LM(cfg)
    params = _params_sds(model)
    pspecs = sh.param_specs(params, mesh)
    n_vis = min(1024, S // 4) if cfg.m_rope else 0

    if kind == "train":
        opt_cfg = AdamWConfig()
        base_step = make_train_step(model, opt_cfg)
        from jax.sharding import PartitionSpec as P
        from repro.models.lm import activation_sharding

        def step(params, opt_state, batch, _dp=dp, _mesh=mesh):
            with activation_sharding(P(_dp), mesh=_mesh):
                return base_step(params, opt_state, batch)
        opt_sds = jax.eval_shape(adamw_init, params)
        # OptState(m, v, step): m/v shard like params, step replicated.
        from repro.optim.adamw import OptState
        opt_specs = OptState(m=pspecs, v=pspecs, step=sh.replicated(mesh))
        batch = make_batch_specs(cfg, B, S - n_vis if cfg.m_rope else S, n_vis=n_vis)
        bspecs = sh.batch_specs(batch, mesh)
        metrics_spec = {"lr": sh.replicated(mesh), "grad_norm": sh.replicated(mesh),
                        "loss": sh.replicated(mesh)}
        return Case(cfg.name, shape_name, step,
                    (params, opt_sds, batch),
                    (pspecs, opt_specs, bspecs),
                    (pspecs, opt_specs, metrics_spec),
                    donate=(0, 1),
                    note=f"n_vis={n_vis}" if n_vis else "")

    if kind == "prefill":
        params = _to_inference_dtype(params)
        pspecs = sh.param_specs(params, mesh)

        from jax.sharding import PartitionSpec as P
        from repro.models.lm import activation_sharding

        def step(params, batch, _dp=dp, _mesh=mesh):
            with activation_sharding(P(_dp), mesh=_mesh):
                return model.prefill(params, batch, S)
        batch = make_batch_specs(cfg, B, S - n_vis if cfg.m_rope else S, n_vis=n_vis)
        bspecs = sh.batch_specs(batch, mesh)
        caches_sds = jax.eval_shape(
            lambda: model.init_caches(B, S, enc_S=cfg.enc_positions))
        cspecs = sh.cache_specs(caches_sds, mesh)
        logit_spec = sh.batch_specs(
            jax.ShapeDtypeStruct((B, cfg.vocab), jnp.float32), mesh)
        return Case(cfg.name, shape_name, step, (params, batch),
                    (pspecs, bspecs), (logit_spec, cspecs),
                    note=f"n_vis={n_vis}" if n_vis else "")

    # ------------------------------------------------------------- decode
    window = None
    note = ""
    if shape_name == "long_500k":
        if cfg.long_ctx_mode == "window":
            window = LONG_WINDOW
            note = f"sliding-window({LONG_WINDOW}) variant (full attention is quadratic)"
        else:
            note = "native state-space decode (O(1)/token)"
    shard_seq = B == 1
    params = _to_inference_dtype(params)
    caches_sds = jax.eval_shape(
        lambda: model.init_caches(B, S, window=window, enc_S=cfg.enc_positions))
    cspecs = sh.cache_specs(caches_sds, mesh, shard_seq=shard_seq)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tspec = sh.batch_specs(tok, mesh)
    pos3 = jax.ShapeDtypeStruct((3, B, 1), jnp.int32) if cfg.m_rope else None

    if cfg.m_rope:
        def step(params, token, caches, pos3):
            return model.decode_step(params, token, caches,
                                     window=window, pos3=pos3)
        args = (params, tok, caches_sds, pos3)
        in_sh = (pspecs, tspec, cspecs, sh.batch_specs(pos3, mesh))
    else:
        def step(params, token, caches):
            return model.decode_step(params, token, caches, window=window)
        args = (params, tok, caches_sds)
        in_sh = (pspecs, tspec, cspecs)
    logit_spec = sh.batch_specs(
        jax.ShapeDtypeStruct((B, cfg.vocab), jnp.float32), mesh)
    return Case(cfg.name, shape_name, step, args, in_sh,
                (logit_spec, cspecs), donate=(2,), note=note)


# ------------------------------------------------------------------- LCSM
def _lcsm_case(cfg: ModelConfig, shape_name: str, mesh) -> Case | Skip:
    from repro.models.hyena import HyenaLCSM

    info = SHAPES[shape_name]
    S, B, kind = info["seq_len"], info["global_batch"], info["kind"]
    model = HyenaLCSM(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspecs = sh.param_specs(params, mesh)

    if kind == "train":
        lm = LM(cfg)
        opt_cfg = AdamWConfig()
        base_step = make_train_step(lm, opt_cfg)
        from jax.sharding import PartitionSpec as P
        from repro.models.lm import activation_sharding
        dp = sh.data_axes(mesh)
        note = ""
        if cfg.d_model < 2048:
            # §Perf P12: at hyena scale (46M params, d=768) 16-way TP costs
            # a 12.6 GB/step activation all-reduce; pure DP over
            # (data×model) replicates the small weights and reduces only
            # ~0.2 GB of gradients.  (*-hyena twins with big d keep TP.)
            dp = ("data", "model")
            params = jax.tree.map(
                lambda s: s, params)  # unchanged SDS; specs replicated below
            pspecs = jax.tree.map(lambda _: sh.replicated(mesh), params)
            note = "pure-DP (d_model too small for TP)"

        def step(params, opt_state, batch, _dp=dp, _mesh=mesh):
            with activation_sharding(P(_dp), mesh=_mesh):
                return base_step(params, opt_state, batch)
        opt_sds = jax.eval_shape(adamw_init, params)
        from repro.optim.adamw import OptState
        opt_specs = OptState(m=pspecs, v=pspecs, step=sh.replicated(mesh))
        batch = make_batch_specs(cfg, B, S)
        if note:  # pure-DP: batch over (data, model)
            from jax.sharding import NamedSharding
            bspecs = jax.tree.map(
                lambda s_: NamedSharding(mesh, P(dp) if s_.shape[0] % 256 == 0
                                         else P()), batch)
        else:
            bspecs = sh.batch_specs(batch, mesh)
        metrics_spec = {"lr": sh.replicated(mesh), "grad_norm": sh.replicated(mesh),
                        "loss": sh.replicated(mesh)}
        return Case(cfg.name, shape_name, step, (params, opt_sds, batch),
                    (pspecs, opt_specs, bspecs),
                    (pspecs, opt_specs, metrics_spec), donate=(0, 1),
                    note=note)

    if kind == "prefill":
        base = lcsm_steps.make_prefill_step(cfg)
        from jax.sharding import PartitionSpec as P
        from repro.models.lm import activation_sharding
        dp = sh.data_axes(mesh)

        def step(params, tokens, _dp=dp, _mesh=mesh):
            with activation_sharding(P(_dp), mesh=_mesh):
                return base(params, tokens)
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tspec = sh.batch_specs(tok, mesh)
        out_spec = sh.batch_specs(
            jax.ShapeDtypeStruct((B, S, cfg.vocab), jnp.float32), mesh)
        return Case(cfg.name, shape_name, step, (params, tok),
                    (pspecs, tspec), out_spec,
                    note="static FFT path (Massaroli Lemma 2.1)")

    # decode: the Flash Inference red step (per-token critical path).
    shard_seq = B == 1
    params = _to_inference_dtype(params)
    pspecs = sh.param_specs(params, mesh)
    bufs = lcsm_steps.buffer_shapes(cfg, B, S)
    bspecs = sh.lcsm_buffer_specs(bufs, mesh, shard_seq=shard_seq)
    red = lcsm_steps.make_red_step(cfg)

    def step(params, streams, b, pos, rho0):
        return red(params, streams, b, pos, rho0)

    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = (params, bufs["streams"], bufs["b"], pos, bufs["rho0"])
    in_sh = (pspecs, bspecs["streams"], bspecs["b"], sh.replicated(mesh),
             bspecs["rho0"])
    tok_spec = sh.batch_specs(jax.ShapeDtypeStruct((B,), jnp.int32), mesh)
    out_sh = (bspecs["streams"], bspecs["b"], tok_spec)
    return Case(cfg.name, shape_name, step, args, in_sh, out_sh,
                donate=(1, 2),
                note="Flash Inference red step (gray tiles lowered separately)")


def build_gray_case(cfg: ModelConfig, shape_name: str, mesh, U: int) -> Case:
    """The side-U gray-tile program for an LCSM arch (Algorithm 3)."""
    info = SHAPES[shape_name]
    S, B = info["seq_len"], info["global_batch"]
    bufs = lcsm_steps.buffer_shapes(cfg, B, S)
    bspecs = sh.lcsm_buffer_specs(bufs, mesh, shard_seq=(B == 1))
    gray = lcsm_steps.make_gray_step(cfg, U, dp=sh.data_axes(mesh), mesh=mesh,
                                     shard_seq=(B == 1))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return Case(cfg.name, f"{shape_name}-gray{U}", gray,
                (bufs["streams"], bufs["b"], pos, bufs["rho"]),
                (bspecs["streams"], bspecs["b"], sh.replicated(mesh),
                 bspecs["rho"]),
                bspecs["b"], donate=(1,), note=f"gray tile U={U}")
