"""Production meshes (TPU v5e numbers; see DESIGN §6).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips — the 'pod' axis is the
slow inter-pod (DCN/ICI-bridge) dimension; only data parallelism (gradient
all-reduce) crosses it.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip), used by the roofline report.
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None):
    """Tiny mesh over the actually-present devices (CPU tests)."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_serving_mesh(data: int = 1, model: int = 1, *, devices=None):
    """(data, model) mesh over the FIRST data*model present devices — unlike
    ``jax.make_mesh`` it does not insist on using every device, so device-count
    scaling sweeps (benchmarks/bench_sharded.py) and sharded-vs-unsharded
    differential tests can build (1,), (2,), (4,) meshes on one forced-host
    process (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
    Serving shards slots over ``data`` and channels over ``model``."""
    import numpy as np
    from jax.sharding import Mesh

    devs = list(jax.devices() if devices is None else devices)
    need = data * model
    if len(devs) < need:
        raise ValueError(
            f"mesh ({data}, {model}) needs {need} devices, have {len(devs)} "
            "(force more with XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return Mesh(np.asarray(devs[:need]).reshape(data, model),
                ("data", "model"))
