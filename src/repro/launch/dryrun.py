import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (arch × input-shape) pair: build the step program, pjit it onto
the production mesh, ``.lower().compile()``, print memory/cost analysis and
write the roofline record to experiments/dryrun/.

  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch hyena --shape decode_32k --multi-pod

The 16×16 single-pod mesh produces the roofline table; the 2×16×16
multi-pod run proves the 'pod' axis shards (gradient all-reduce crosses
pods; everything else stays intra-pod).
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED, get_config
from repro.launch.analysis import analytic_flops, analyze, model_flops_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, Skip, build_case, build_gray_case

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_case(case, mesh, mesh_name: str, cfg, shape_key: str, out_dir: str,
             quiet: bool = False):
    t0 = time.perf_counter()
    jitted = jax.jit(case.step_fn, in_shardings=case.in_shardings,
                     out_shardings=case.out_shardings,
                     donate_argnums=case.donate)
    with mesh:
        lowered = jitted.lower(*case.args)
        compiled = lowered.compile()
    t1 = time.perf_counter()
    ma = compiled.memory_analysis()
    chips = mesh.devices.size
    rf = analyze(case.arch, case.shape, mesh_name, chips, compiled,
                 model_flops=model_flops_for(cfg, shape_key),
                 analytic=analytic_flops(cfg, shape_key), note=case.note)
    rec = rf.to_dict()
    rec["compile_s"] = t1 - t0
    rec["memory_analysis"] = {
        k: float(getattr(ma, k, 0) or 0)
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
    } if ma else {}
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{case.arch}__{case.shape}__{mesh_name}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    if not quiet:
        gib = rec["memory_analysis"].get("argument_size_in_bytes", 0) / 2**30
        tmp = rec["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
        print(f"  OK   {case.arch:28s} {case.shape:22s} {mesh_name:9s} "
              f"compile {rec['compile_s']:6.1f}s  args {gib:7.2f} GiB/chip  "
              f"temp {tmp:6.2f} GiB  flops/chip {rf.hlo_flops:.3e}  "
              f"bottleneck {rf.bottleneck}"
              + (f"  [{case.note}]" if case.note else ""))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="'all', 'assigned', or comma-separated arch names")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true",
                    help="also run the 2x16x16 multi-pod mesh")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--gray-tiles", default="",
                    help="comma-sep tile sides to lower for LCSM decode shapes")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    if args.arch in ("all", "assigned"):
        archs = list(ASSIGNED) + (["hyena"] if args.arch == "all" else [])
    else:
        archs = args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = []
    if not args.multi_pod_only:
        meshes.append(("pod16x16", False))
    if args.multi_pod or args.multi_pod_only:
        meshes.append(("pod2x16x16", True))

    n_ok = n_skip = n_fail = 0
    for mesh_name, mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        print(f"== mesh {mesh_name}: {mesh.devices.size} chips {dict(mesh.shape)}")
        for arch in archs:
            cfg = get_config(arch)
            for shape in shapes:
                try:
                    case = build_case(cfg, shape, mesh)
                    if isinstance(case, Skip):
                        print(f"  SKIP {arch:28s} {shape:22s} {mesh_name:9s} {case.reason}")
                        n_skip += 1
                        continue
                    run_case(case, mesh, mesh_name, cfg, shape, args.out)
                    n_ok += 1
                    if (cfg.family == "lcsm" and shape in ("decode_32k", "long_500k")
                            and args.gray_tiles):
                        for u in args.gray_tiles.split(","):
                            gc = build_gray_case(cfg, shape, mesh, int(u))
                            run_case(gc, mesh, mesh_name, cfg, shape, args.out)
                            n_ok += 1
                except Exception:
                    n_fail += 1
                    print(f"  FAIL {arch:28s} {shape:22s} {mesh_name}")
                    traceback.print_exc(limit=8)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
