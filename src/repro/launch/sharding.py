"""Pytree → PartitionSpec rules for the production mesh (DESIGN §6).

Weights are 2-D sharded (FSDP×TP): d_in→data, d_out→model (or transposed),
experts→model, vocab unsharded (51865 isn't 16-divisible), norms/bias
replicated.  Stacked layer axes (scan repeat dims) are unsharded.

Rules are *divisibility-guarded*: an axis is only assigned if the mesh axis
size divides the dim, so the same rule table serves the 256-chip pod, the
512-chip multi-pod and the 1-device CPU test mesh.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        s = 1
        for n in name:
            s *= _axis_size(mesh, n)
        return s
    return mesh.shape[name] if name in mesh.shape else 0


def _guard(mesh: Mesh, spec: tuple, shape: tuple) -> P:
    """Drop axes whose size doesn't divide the dim (or don't exist)."""
    fixed = []
    for dim, ax in zip(shape, spec):
        size = _axis_size(mesh, ax)
        fixed.append(ax if size and dim % size == 0 else None)
    return P(*fixed)


def data_axes(mesh: Mesh):
    """The (super-)axis batch shards over: ('pod','data') when multi-pod."""
    return ("pod", "data") if "pod" in mesh.shape else "data"


# ------------------------------------------------------------------ params
# (regex on the pytree path, base spec applied to the TRAILING dims).
_RULES: list[tuple[str, tuple]] = [
    (r"\['(emb|unemb)'\]$",                      (None, "model")),
    (r"\['router'\]\['w'\]$",                    (None, None)),
    # MoE experts: (E, d_in, d_ff) / (E, d_ff, d_out)
    (r"\['w1'\]$|\['w3'\]$",                     ("model", "data", None)),
    (r"\['w2'\]$",                               ("model", None, "data")),
    # attention / projections (these fire before the generic w1/w2 above
    # because the list is scanned in order and these paths are longer).
    (r"\['(wq|wk|wv|wq_a|wq_b|wkv_a|wkv_b|in_proj|x_proj)'\]\['w'\]$", ("data", "model")),
    (r"\['(wo|out_proj)'\]\['w'\]$",             ("model", "data")),
    (r"\['dt_proj'\]\['w'\]$",                   (None, "model")),
    (r"\['(fc1|fc2|fc3)'\]\['w'\]$",             ("data", "model")),
    (r"\['proj'\]\['w'\]$",                      ("data", "model")),
    # dense swiglu inside 'mlp'/'shared' dicts: 2-D (d, ff) / (ff, d)
    (r"\['(mlp|shared)'\]\['(w1|w3)'\]\['w'\]$", ("data", "model")),
    (r"\['(mlp|shared)'\]\['w2'\]\['w'\]$",      ("model", "data")),
    # mamba
    (r"\['conv_w'\]$",                           (None, "model")),
    (r"\['conv_b'\]$",                           ("model",)),
    (r"\['A_log'\]$",                            ("model", None)),
    (r"\['D'\]$",                                ("model",)),
    # hyena implicit filters
    (r"\['short_w'\]$",                          (None, "model")),
    (r"\['alphas'\]$",                           (None, "model")),
]


def param_spec_for_path(path_str: str, ndim: int, shape: tuple, mesh: Mesh) -> P:
    base: tuple | None = None
    for pat, spec in _RULES:
        if re.search(pat, path_str):
            base = spec
            break
    if base is None:
        return P()  # norms, biases, scalars: replicated
    if len(base) > ndim:  # e.g. 1-D bias matched a 2-D rule — replicate
        return P()
    # left-pad with None for stacked leading axes (scan repeat dims)
    full = (None,) * (ndim - len(base)) + base
    return _guard(mesh, full, shape)


def param_specs(params: Any, mesh: Mesh) -> Any:
    def spec(path, leaf):
        ps = param_spec_for_path(jax.tree_util.keystr(path), leaf.ndim,
                                 leaf.shape, mesh)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(spec, params)


# ----------------------------------------------------------------- batches
def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Shard the batch (leading) axis over (pod, data); pos3 has its batch
    axis second."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        key = jax.tree_util.keystr(path)
        if "pos3" in key:
            ps = _guard(mesh, (None, dp, None), leaf.shape)
        else:
            ps = _guard(mesh, (dp,) + (None,) * (leaf.ndim - 1), leaf.shape)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(spec, batch)


def token_specs(tok: Any, mesh: Mesh) -> Any:
    return batch_specs(tok, mesh)


# ------------------------------------------------------------------ caches
def cache_specs(caches: Any, mesh: Mesh, *, shard_seq: bool = False) -> Any:
    """Decode caches. Leaves are (repeat, B, ...).

    KV-like caches shard batch→(pod,data) AND sequence→model: the S axis
    carries the bulk of decode state, and sequence-parallel attention only
    needs tiny softmax-stat / output all-reduces (vs. all-gathering the
    cache if S were replicated over model).  kv_heads (2–8 < 16) stay
    replicated.  ``shard_seq`` (long_500k, B=1): the batch axis can't
    shard, so S takes BOTH axes (data, model).
    """
    dp = data_axes(mesh)
    seq_ax = ("data", "model") if shard_seq else "model"
    b_ax = None if shard_seq else dp

    def spec(path, leaf):
        key = jax.tree_util.keystr(path)
        nd = leaf.ndim
        if nd <= 1:
            ps = P()
        elif "pos" in key and nd == 2:
            ps = _guard(mesh, (None, b_ax), leaf.shape)
        elif "ssm" in key and nd >= 3:
            # (repeat, B, d_inner, N): batch→dp, channels→model
            ps = _guard(mesh, (None, b_ax, "model") + (None,) * (nd - 3), leaf.shape)
        elif "conv" in key and nd >= 3:
            ps = _guard(mesh, (None, b_ax, None, "model")[: nd], leaf.shape)
        elif nd >= 3:
            # KV / MLA / cross caches: (repeat, B, S, ...) — S→model
            ps = _guard(mesh, (None, b_ax, seq_ax) + (None,) * (nd - 3), leaf.shape)
        else:
            ps = _guard(mesh, (None, b_ax) + (None,) * (nd - 2), leaf.shape)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(spec, caches)


# ---------------------------------------------------- FlashEngine state
def engine_state_specs(state: Any, mesh: Mesh, *, data_axis: Any = "data",
                       model_axis: Any = "model") -> Any:
    """Shardings for FlashEngine's EngineState (and any pytree whose leaves
    are (B, Lbuf, C) buffers): serving slots (batch) → ``data_axis``,
    channels → ``model_axis``, the time axis replicated (every tile slices a
    traced position window; an L-sharded buffer would all-gather per step —
    same rationale as ``lcsm_buffer_specs``).  Divisibility-guarded like the
    param rules, so the same call serves any mesh including the 1-device
    test mesh.  Works on concrete arrays and ShapeDtypeStructs alike."""
    def spec(leaf):
        if leaf.ndim != 3:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, _guard(mesh, (data_axis, None, model_axis), leaf.shape))

    return jax.tree.map(spec, state)


# ------------------------------------------------------------- LCSM buffers
def lcsm_buffer_specs(bufs: Any, mesh: Mesh, *, shard_seq: bool) -> Any:
    """Flash-Inference plane-stacked buffers (see launch/lcsm_steps.py):
      streams/b : (planes, B, Lbuf, D)  — batch→(pod,data), D→model
      rho       : (levels, Lbuf, D)     — D→model
      rho0      : (levels, D)
    ``shard_seq`` (long_500k, B=1): D takes BOTH axes, L replicated —
    slicing a traced position from an L-sharded buffer all-gathers it."""
    dp = data_axes(mesh)
    ch = ("data", "model") if shard_seq else "model"

    def spec(path, leaf):
        nd = leaf.ndim
        if nd == 4:  # (planes, B, L, D)
            ps = _guard(mesh, (None, None if shard_seq else dp, None, ch),
                        leaf.shape)
        elif nd == 3:  # rho (levels, L, D)
            ps = _guard(mesh, (None, None, ch), leaf.shape)
        elif nd == 2:  # rho0 (levels, D)
            ps = _guard(mesh, (None, ch), leaf.shape)
        else:
            ps = P()
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(spec, bufs)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
