"""Flash-Inference decode as pure, mesh-lowerable step functions.

repro.core.engine.FlashEngine is the host-driving implementation (it owns
the schedule and per-tile-size jits).  For the multi-pod dry-run we need the
same two computations as *pure functions of (buffers, position)* so pjit can
lower them with ShapeDtypeStructs and explicit shardings:

  * ``red_step``   — Algorithm 2 lines 6–8 + sampling: the per-token
    sequential critical path (runs every token).
  * ``gray_step_U``— Algorithm 3 lines 10–12 for one static tile side U:
    the across-layer-batched τ call (amortized O(log²L)/token).

Buffer layout (mesh-native, beyond the engine's packed channels): every
Hyena stream lives in its own (B, L, D) plane of ONE stacked tensor

    streams: (5·n_ops + 1, B, L, D)   planes per op k:
        5k+0 v_raw | 5k+1 x1_raw | 5k+2 x2_raw | 5k+3 u | 5k+4 v1
    plane 5·n_ops: final operator output z
    b:       (2·n_ops, B, L, D)       mixer accumulators (level order)
    rho:     (2·n_ops, L, D), rho0: (2·n_ops, D)

Rationale: the engine's packed layout (concat'd channel groups of widths
4D/3D/D) forces channel slices that are NOT aligned to model-axis shard
boundaries — GSPMD inserts collective-permutes on every level (measured
5.4 GB/step).  With uniform D-wide planes, every slice is shard-aligned,
τ is channel-separable, and the whole decode step runs collective-free
except the final logits reduction.

Sharding: planes replicated on axis 0; batch→(pod,data); D→model.  For
long_500k (B = 1), D takes BOTH axes and L stays replicated — slicing a
traced position from an L-sharded buffer all-gathers it (measured 10 GB);
channel sharding keeps every read local.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import tau as tau_mod
from repro.models import components as C
from repro.models.hyena import HyenaLCSM

_F32 = jnp.float32


def n_streams(cfg: ModelConfig) -> int:
    n_ops = cfg.n_layers // (cfg.hyena_order - 1)
    return 5 * n_ops + 1


def buffer_shapes(cfg: ModelConfig, batch: int, Lbuf: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for {streams, b, rho, rho0}."""
    n_ops = cfg.n_layers // (cfg.hyena_order - 1)
    D = cfg.d_model
    sds = jax.ShapeDtypeStruct
    return {
        "streams": sds((n_streams(cfg), batch, Lbuf, D), dtype),
        "b": sds((2 * n_ops, batch, Lbuf, D), _F32),
        "rho": sds((2 * n_ops, Lbuf, D), _F32),
        "rho0": sds((2 * n_ops, D), _F32),
    }


def materialize_buffers(cfg: ModelConfig, params, batch: int, Lbuf: int,
                        dtype=jnp.float32):
    """Concrete zero buffers + real (composed) filters — host-scale tests."""
    model = HyenaLCSM(cfg)
    shapes = buffer_shapes(cfg, batch, Lbuf, dtype)
    rho = jnp.stack(model.filters(params, Lbuf))  # (2n_ops, Lbuf, D)
    return {
        "streams": jnp.zeros(shapes["streams"].shape, dtype),
        "b": jnp.zeros(shapes["b"].shape, _F32),
        "rho": rho,
        "rho0": rho[:, 0],
    }


def _starts(pos, *parts):
    """dynamic_slice start tuple: every entry cast to the traced position's
    dtype — x64 mode would otherwise promote the Python-int plane indices
    to int64 and lax rejects the int32/int64 mix."""
    dt = jnp.asarray(pos).dtype
    return tuple(jnp.asarray(p, dt) for p in parts)


def _plane(streams, idx: int, pos, T: int):
    """(B, T, D) window of plane ``idx`` ending at pos+T-1 (static idx,
    traced pos)."""
    _, B, _, D = streams.shape
    return jax.lax.dynamic_slice(
        streams, _starts(pos, idx, 0, pos, 0), (1, B, T, D))[0]


def _write(streams, idx: int, pos, val):
    """Write (B, T, D) into plane idx at time pos."""
    return jax.lax.dynamic_update_slice(
        streams, val[None].astype(streams.dtype), _starts(pos, idx, 0, pos, 0))


def seed_first_token(cfg: ModelConfig, params, bufs, tok0: jnp.ndarray,
                     pos: int = 0):
    """Write the first token's streams at ``pos`` (host-scale tests)."""
    model = HyenaLCSM(cfg)
    e = params["emb"][tok0]  # (B, D)
    op0 = params["ops"][0]
    z = C.dense(C.rms_norm(e, op0["norm1"]), op0["in_proj"]["w"])  # (B, 3D)
    v, x1, x2 = jnp.split(z, 3, axis=-1)
    s = bufs["streams"]
    for i, val in enumerate((v, x1, x2, e)):
        s = _write(s, i, pos, val[:, None])
    return dict(bufs, streams=s)


def make_red_step(cfg: ModelConfig):
    """red_step(params, streams, b, pos, rho0) -> (streams, b, token).

    One full serve step: finalize position ``pos`` at every level (red
    cells + blocks, sequential across ops by data dependency), greedy-
    sample, and write the next token's operator-0 streams at pos+1.
    ``b`` is returned unchanged (red cells read it; accumulation into b is
    the gray steps' job) — pos must be >= ctx_window (true for the decode
    shapes, which resume from a long prefix).
    """
    model = HyenaLCSM(cfg)
    D = cfg.d_model
    w = model.ctx_window
    n_ops = model.n_ops

    def shortconv_at(streams, idx, pos, taps):
        win = _plane(streams, idx, pos - w, w + 1)  # (B, w+1, D)
        return C.causal_shortconv_from_window(win, taps, 1)  # (B, 1, D)

    def red_step(params, streams, b, pos, rho0):
        B = streams.shape[1]
        z = None
        for k in range(n_ops):
            op = params["ops"][k]
            # level 2k: b1 red cell + gate with shortconv(x1)
            vp = _plane(streams, 5 * k + 0, pos, 1)
            b1 = jax.lax.dynamic_slice(
                b, _starts(pos, 2 * k, 0, pos, 0), (1, B, 1, D))[0]
            b1 = b1 + vp.astype(_F32) * rho0[2 * k]
            x1 = shortconv_at(streams, 5 * k + 1, pos, op["short_w"][:, D:2 * D])
            v1 = (x1 * b1.astype(x1.dtype))
            streams = _write(streams, 5 * k + 4, pos, v1)
            # level 2k+1: b2 red cell + gate with shortconv(x2), finish op
            b2 = jax.lax.dynamic_slice(
                b, _starts(pos, 2 * k + 1, 0, pos, 0), (1, B, 1, D))[0]
            b2 = b2 + v1.astype(_F32) * rho0[2 * k + 1]
            x2 = shortconv_at(streams, 5 * k + 2, pos, op["short_w"][:, 2 * D:3 * D])
            u = _plane(streams, 5 * k + 3, pos, 1)
            y = u + C.dense(x2 * b2.astype(x2.dtype), op["out_proj"]["w"])
            z = y + C.swiglu(op["mlp"], C.rms_norm(y, op["norm2"]))
            if k + 1 < n_ops:
                nxt = params["ops"][k + 1]
                zp = C.dense(C.rms_norm(z, nxt["norm1"]), nxt["in_proj"]["w"])
                v_, x1_, x2_ = jnp.split(zp, 3, axis=-1)
                for off, val in ((0, v_), (1, x1_), (2, x2_), (3, z)):
                    streams = _write(streams, 5 * (k + 1) + off, pos, val)
            else:
                streams = _write(streams, 5 * n_ops, pos, z)
        # sample next token, write operator-0 streams at pos+1
        logits = model.logits(params, z[:, 0])
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        e = params["emb"][token]
        op0 = params["ops"][0]
        zp = C.dense(C.rms_norm(e, op0["norm1"]), op0["in_proj"]["w"])
        v_, x1_, x2_ = jnp.split(zp, 3, axis=-1)
        for off, val in ((0, v_), (1, x1_), (2, x2_), (3, e)):
            streams = _write(streams, off, pos + 1, val[:, None])
        return streams, b, token

    return red_step


def make_gray_step(cfg: ModelConfig, U: int, *, dp=None, mesh=None,
                   shard_seq: bool = False, seq_level_min: int = 2048):
    """gray_step(streams, b, pos, rho) -> b.

    Accounts the side-U tile at step ``pos``: contribution of the conv
    streams at [pos-U+1, pos] to b at [pos+1, pos+U] — ALL 2·n_ops levels
    in one batched τ (Algorithm 3).  FFT path = order-2U circular conv
    (Appendix C, filter DFTs implicit).

    Parallelization policy per the paper:
      * U < seq_level_min — levels batched (saturate bandwidth, Alg. 3);
      * U ≥ seq_level_min — levels sequential (Appendix E: O(L·D) extra
        memory instead of O(M·L·D), no real time cost).

    Under shard_map each chip convolves only its (batch, channel) shard —
    τ is channel-separable so gray tiles are collective-free.  (GSPMD
    alone replicates FFT operands: 27 GiB/chip temp measured.)
    """
    model = HyenaLCSM(cfg)
    D = cfg.d_model
    n_ops = model.n_ops
    # conv-input plane per level: 2k -> v of op k, 2k+1 -> v1 of op k.
    plane_idx = []
    for k in range(n_ops):
        plane_idx += [5 * k + 0, 5 * k + 4]
    plane_idx = jnp.asarray(plane_idx)

    def tau_all_levels(y, r):
        if U <= 16:
            return tau_mod.tau_direct(y, r)
        if U >= seq_level_min:
            return jax.lax.map(
                lambda xs: tau_mod.tau_fft(xs[0][None], rho2u=xs[1][None])[0],
                (y, r[:, 0]))
        return tau_mod.tau_fft(y, rho2u=r)

    def gray_step(streams, b, pos, rho):
        B = streams.shape[1]
        seg = jax.lax.dynamic_slice(
            streams, _starts(pos, 0, 0, pos - U + 1, 0),
            (streams.shape[0], B, U, D))
        ins = jnp.take(seg, plane_idx, axis=0).astype(_F32)  # (2n_ops,B,U,D)
        rho2u = rho[:, None, : 2 * U]  # (2n_ops, 1, 2U, D)

        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            if shard_seq:
                ispec = P(None, None, None, ("data", "model"))
                rspec = P(None, None, None, ("data", "model"))
            else:
                ispec = P(None, dp, None, "model")
                rspec = P(None, None, None, "model")
            out = shard_map(tau_all_levels, mesh=mesh,
                            in_specs=(ispec, rspec), out_specs=ispec,
                            check_rep=False)(ins, rho2u)
        else:
            out = tau_all_levels(ins, rho2u)

        cur = jax.lax.dynamic_slice(b, _starts(pos, 0, 0, pos + 1, 0),
                                    (b.shape[0], B, U, D))
        return jax.lax.dynamic_update_slice(
            b, cur + out.astype(_F32), _starts(pos, 0, 0, pos + 1, 0))

    return gray_step


def make_prefill_step(cfg: ModelConfig):
    """Static-FFT prompt ingestion (train-time path) — lowers prefill_32k
    for LCSM archs: tokens (B, P) -> logits (B, P, V)."""
    model = HyenaLCSM(cfg)

    def prefill(params, tokens):
        return model.forward_tokens(params, tokens)

    return prefill


def compact_buffers(bufs: dict, keep_from: int) -> dict:
    """Appendix D: once generation passes position ``keep_from`` (= L/2),
    no tile ever reads positions < keep_from again (proven in
    tests/test_system.py::test_half_activation_memory_appendix_d), so the
    buffers can be shifted down in place — halving the live activation
    footprint.  Positions map p → p - keep_from; filter LAGS are shift-
    invariant (contribution of a_i to b_t depends only on t - i), so the
    same red/gray step programs continue unchanged on the compacted
    buffers.  rho needs no shift (it is indexed by lag, not position).
    """
    def shift(x):
        L = x.shape[2]
        seg = jax.lax.dynamic_slice_in_dim(x, keep_from, L - keep_from, axis=2)
        return jnp.pad(seg, ((0, 0),) * 2 + ((0, keep_from),) + ((0, 0),))

    return dict(bufs, streams=shift(bufs["streams"]), b=shift(bufs["b"]))
