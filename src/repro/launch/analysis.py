"""Roofline-term extraction from a compiled dry-run artifact (DESIGN §7).

    compute    = HLO_FLOPs / (chips × 197e12)          [s]
    memory     = HLO_bytes / (chips × 819e9)           [s]
    collective = collective_bytes / (chips × 50e9)     [s]

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the optimized (post-SPMD) HLO text
and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute / ragged-all-to-all op
(result bytes ≈ data moved per chip for these ops; noted in EXPERIMENTS).
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def cost_analysis_dict(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: recent jaxlib returns a
    one-element list of dicts (one per program), older versions a plain
    dict, and it may be None.  Always returns a (possibly empty) dict."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)

# e.g.:  %all-reduce.7 = f32[32,1024]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<dtype>[a-z]\d*|pred|bf16)\[(?P<dims>[\d,]*)\][^ ]*)\s+"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")

_TUPLE_RE = re.compile(
    r"=\s*\((?P<parts>[^)]*)\)\s+(?P<op>" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_PART_RE = re.compile(r"(?P<dtype>[a-z]\d+|pred|bf16)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> tuple[int, dict[str, int]]:
    """Sum result bytes of collective ops in (optimized) HLO text.
    '-start' variants counted once; '-done' skipped (same data)."""
    total = 0
    per_op: dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if m and m.group("dtype"):
            b = _shape_bytes(m.group("dtype"), m.group("dims"))
            per_op[m.group("op")] = per_op.get(m.group("op"), 0) + b
            total += b
            continue
        m = _TUPLE_RE.search(line)
        if m:
            b = sum(_shape_bytes(p.group("dtype"), p.group("dims"))
                    for p in _PART_RE.finditer(m.group("parts")))
            per_op[m.group("op")] = per_op.get(m.group("op"), 0) + b
            total += b
    return total, per_op


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: dict
    bytes_per_chip: float        # peak HBM per device from memory_analysis
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6·N_active·D (analytic)
    analytic_flops: float        # model + attention terms (program total)
    useful_ratio: float          # model_flops / total program flops
    note: str = ""

    def to_dict(self):
        return asdict(self)


def analyze(arch: str, shape: str, mesh_name: str, chips: int, compiled,
            model_flops: float, analytic: float = 0.0, note: str = "") -> Roofline:
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    cb, per_op = collective_bytes(hlo)
    ma = compiled.memory_analysis()
    per_chip = float(getattr(ma, "temp_size_in_bytes", 0) +
                     getattr(ma, "argument_size_in_bytes", 0) +
                     getattr(ma, "output_size_in_bytes", 0)) if ma else 0.0

    # cost_analysis flops/bytes are per-program = per-chip under SPMD, BUT
    # while-loop bodies are counted ONCE (not × trip count) — scanned
    # programs under-report.  The compute term therefore takes
    # max(HLO, analytic/chips).
    flops_eff = max(flops, analytic / chips)
    compute_s = flops_eff / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    collective_s = cb / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * chips, analytic) \
        if max(flops, analytic) else float("nan")
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=cb,
        coll_breakdown=per_op, bytes_per_chip=per_chip,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        analytic_flops=analytic, useful_ratio=useful, note=note)


# ----------------------------------------------------------- model FLOPs
def count_params(cfg) -> float:
    """Analytic parameter counts (total and active) from the config."""
    D, V = cfg.d_model, cfg.vocab
    hd = cfg.head_dim
    per_layer_attn = D * (cfg.n_heads * hd) + 2 * D * (cfg.n_kv_heads * hd) \
        + (cfg.n_heads * hd) * D
    if cfg.use_mla:
        vhd = cfg.v_head_dim or hd
        per_layer_attn = (D * cfg.q_lora + cfg.q_lora * cfg.n_heads * (hd + cfg.rope_dim)
                          + D * (cfg.kv_lora + cfg.rope_dim)
                          + cfg.kv_lora * cfg.n_heads * (hd + vhd)
                          + cfg.n_heads * vhd * D)
    dense_ffn = 3 * D * cfg.d_ff if cfg.d_ff else 0
    moe_ffn_all = 3 * D * (cfg.moe_d_ff or 0) * cfg.n_experts
    moe_ffn_act = 3 * D * (cfg.moe_d_ff or 0) * (cfg.top_k + cfg.n_shared_experts)
    d_inner = cfg.d_inner or 2 * D
    mamba_l = D * 2 * d_inner + d_inner * (max(1, D // 16) + 2 * cfg.ssm_state) \
        + max(1, D // 16) * d_inner + d_inner * D

    total = active = V * D * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "lcsm":
        n_ops = cfg.n_layers // (cfg.hyena_order - 1)
        per_op = D * 3 * D + D * D + 3 * D * cfg.d_ff  # in/out proj + swiglu
        total = active = V * D + n_ops * per_op
        return total, active
    if cfg.family == "gla":
        dk = cfg.gla_dk or D
        dv = cfg.gla_dv or D
        # q/k/v projections + out_proj + swiglu, per layer (tied embedding)
        per_layer = D * dk * 2 + D * dv + dv * D + 3 * D * cfg.d_ff
        total = active = V * D + cfg.n_layers * per_layer
        return total, active
    for stack in cfg.stacks():
        for ld in stack.pattern:
            n = stack.repeat
            mix = {"attn": per_layer_attn, "attn_cross": 2 * per_layer_attn,
                   "mla": per_layer_attn, "mamba": mamba_l}[ld.mixer]
            total += n * mix
            active += n * mix
            if ld.ffn == "dense":
                total += n * dense_ffn
                active += n * dense_ffn
            elif ld.ffn == "moe":
                total += n * moe_ffn_all
                active += n * moe_ffn_act
    if cfg.enc_layers:
        total += cfg.enc_layers * (per_layer_attn + 3 * D * cfg.d_ff)
        active += cfg.enc_layers * (per_layer_attn + 3 * D * cfg.d_ff)
    return total, active


def model_flops_for(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only), N = active params,
    D = tokens processed by the program."""
    from repro.launch.specs import SHAPES

    info = SHAPES[shape_name]
    _, active = count_params(cfg)
    if info["kind"] == "train":
        toks = info["seq_len"] * info["global_batch"]
        return 6.0 * active * toks
    if info["kind"] == "prefill":
        toks = info["seq_len"] * info["global_batch"]
        return 2.0 * active * toks
    # decode: one token per sequence
    return 2.0 * active * info["global_batch"]


def attn_flops_for(cfg, shape_name: str) -> float:
    """Attention score/value contraction FLOPs (absent from 6·N·D).
    Causal full-seq: 2·(QK + PV)·B·T²/2·H·hd per layer; ×3 for train
    (fwd + ~2× bwd).  Decode: one query row against the cache."""
    from repro.launch.specs import LONG_WINDOW, SHAPES

    if cfg.family in ("ssm", "lcsm", "gla"):  # no softmax-attention layers
        return 0.0
    info = SHAPES[shape_name]
    T, B, kind = info["seq_len"], info["global_batch"], info["kind"]
    n_attn = sum(1 for st in cfg.stacks() for ld in st.pattern
                 if ld.mixer in ("attn", "mla", "attn_cross")) and \
        sum(st.repeat * sum(1 for ld in st.pattern
                            if ld.mixer in ("attn", "mla", "attn_cross"))
            for st in cfg.stacks())
    hd = (cfg.head_dim + cfg.rope_dim) if cfg.use_mla else cfg.head_dim
    H = cfg.n_heads
    if kind == "train":
        return 3.0 * n_attn * 2 * B * T * T * H * hd  # ≈ (QK+PV)·T²/2·2 ·3
    if kind == "prefill":
        return n_attn * 2 * B * T * T * H * hd
    S_ctx = min(T, LONG_WINDOW) if (B == 1 and cfg.long_ctx_mode == "window") else T
    return n_attn * 4.0 * B * S_ctx * H * hd


def analytic_flops(cfg, shape_name: str) -> float:
    """Lower-bound analytic FLOPs for the whole program — used alongside
    HLO flops because XLA's cost_analysis counts while-loop bodies ONCE
    (scan over layers / microbatches under-reports by the trip count)."""
    return model_flops_for(cfg, shape_name) + attn_flops_for(cfg, shape_name)
