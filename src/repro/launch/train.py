"""Multi-pod training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 100 --global-batch 8 --seq-len 256 [--production-mesh]

Default: a host mesh over the actually-present devices (runs real steps).
``--production-mesh``: the 16×16 / 2×16×16 mesh (placeholder devices — use
only for dry-run-style verification; see repro.launch.dryrun for the
compile-only path).
"""

from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.launch import sharding as sh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.lm import LM, activation_sharding
from repro.optim import AdamWConfig, adamw_init
from repro.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    dp = sh.data_axes(mesh)

    model = LM(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 10))
    base_step = make_train_step(model, opt_cfg)

    def step_fn(params, opt_state, batch):
        with activation_sharding(P(dp)):
            return base_step(params, opt_state, batch)

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        pspecs = sh.param_specs(params, mesh)
        params = jax.device_put(params, pspecs)
        opt_state = adamw_init(params)
        from repro.optim.adamw import OptState
        ospecs = OptState(m=pspecs, v=pspecs, step=sh.replicated(mesh))

        step = jax.jit(step_fn, in_shardings=(pspecs, ospecs, None),
                       out_shardings=(pspecs, ospecs, None),
                       donate_argnums=(0, 1))
        ds = SyntheticLMDataset(cfg, global_batch=args.global_batch,
                                seq_len=args.seq_len,
                                n_vis=min(16, args.seq_len // 4) if cfg.m_rope else 0)
        t0 = time.perf_counter()
        for i in range(args.steps):
            batch = ds.batch(i)
            batch = jax.device_put(batch, sh.batch_specs(batch, mesh))
            params, opt_state, metrics = step(params, opt_state, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"({time.perf_counter() - t0:.1f}s)")
        if args.ckpt_dir:
            from repro.checkpoint import save_checkpoint

            save_checkpoint(args.ckpt_dir, args.steps,
                            {"params": params, "opt": opt_state})
            print(f"checkpoint -> {args.ckpt_dir}/step_{args.steps:08d}")


if __name__ == "__main__":
    main()
