"""Sharded npz checkpointing for arbitrary pytrees.

Layout: <dir>/step_<n>/shard_<k>.npz + manifest.json.  Leaves are keyed by
their pytree path string; large leaves are split across shards by a simple
bytes budget (so no single npz exceeds ~1 GiB and multi-host writers could
each own a disjoint shard set).  Restore rebuilds onto the caller-provided
pytree structure (dtypes/shapes validated).
"""

from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import ml_dtypes
import numpy as np

_SHARD_BUDGET = 1 << 30  # bytes per shard file


def _leaf_key(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    shards: list[dict[str, np.ndarray]] = [{}]
    sizes = [0]
    manifest = {}
    for path, leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype == ml_dtypes.bfloat16:  # npz has no bf16: store bit-pattern
            arr = arr.view(np.uint16)
            logical = "bfloat16"
        else:
            logical = str(arr.dtype)
        if sizes[-1] + arr.nbytes > _SHARD_BUDGET and shards[-1]:
            shards.append({})
            sizes.append(0)
        shard_id = len(shards) - 1
        key = _leaf_key(path)
        shards[-1][key] = arr
        sizes[-1] += arr.nbytes
        manifest[key] = {"shard": shard_id, "shape": list(arr.shape),
                         "dtype": logical}
    for i, shard in enumerate(shards):
        # npz keys cannot contain '/': escape.
        np.savez(os.path.join(out, f"shard_{i}.npz"),
                 **{k.replace("/", "\\"): v for k, v in shard.items()})
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump({"step": step, "n_shards": len(shards), "leaves": manifest}, f)
    return out


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    files = [np.load(os.path.join(src, f"shard_{i}.npz"))
             for i in range(manifest["n_shards"])]
    leaves_like = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves_like[0]:
        key = _leaf_key(path)
        meta = manifest["leaves"][key]
        arr = files[meta["shard"]][key.replace("/", "\\")]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        want = np.asarray(leaf)
        if tuple(arr.shape) != want.shape or str(arr.dtype) != str(want.dtype):
            raise ValueError(
                f"checkpoint leaf {key}: have {arr.shape}/{arr.dtype}, "
                f"want {want.shape}/{want.dtype}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(leaves_like[1], out)
