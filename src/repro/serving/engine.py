"""Batched autoregressive serving with continuous batching.

Fixed-slot design (the vLLM-style scheduler reduced to its core): the
engine owns B slots, each bound to one in-flight request.  Every call to
``step()`` advances ALL slots by one token with a single jitted
``decode_step``.  Finished slots (EOS or max_new) are refilled from the
admission queue: the new request is prefilled with batch=1 and its cache
rows written into the batched cache at that slot (pure dynamic_update_slice
on every cache leaf) — no other slot is disturbed, no recompile (shapes are
static in B and S).

Per-family caches come from models/lm.py: KV (GQA), MLA latent, SSM state,
cross-KV — the engine is cache-agnostic (pytree surgery only).
LCSM archs use serving/lcsm_backend.py instead (FlashEngine decode).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import LM


@dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (P,) int32
    max_new: int
    eos_id: int = -1                # -1: never stops early
    out: list[int] = field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, n_slots: int,
                 max_seq: int, window: int | None = None,
                 cache_dtype=jnp.bfloat16, mesh=None):
        assert cfg.family != "lcsm", "use LCSMServer for LCSM archs"
        self.cfg = cfg
        self.model = LM(cfg)
        self.params = params
        self.B = n_slots
        self.S = max_seq
        self.window = window
        self.cache_dtype = cache_dtype
        self.mesh = mesh
        self.caches = self.model.init_caches(
            n_slots, max_seq, dtype=cache_dtype, window=window)
        if mesh is not None:
            # Same mesh contract as the LCSM backend: slots→data (cache batch
            # axis), decode state→model where divisible; params replicated.
            # The spec helpers live in launch/sharding (reused, not forked).
            from repro.launch.sharding import cache_specs, replicated
            self.caches = jax.device_put(
                self.caches, cache_specs(self.caches, mesh))
            self.params = jax.device_put(
                params, jax.tree.map(lambda _: replicated(mesh), params))
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []

        # caches are donated: decode_step aliases every cache leaf in place
        # instead of copying the whole KV/state footprint per token.  After a
        # _decode call the old self.caches buffers are dead — step() is the
        # only caller and always reassigns.
        self._decode = jax.jit(functools.partial(
            self.model.decode_step, window=window), donate_argnums=(2,))
        self._prefill1 = jax.jit(functools.partial(
            self.model.prefill, window=window, cache_dtype=cache_dtype),
            static_argnames=("S_cap",))

    # ------------------------------------------------------------ admission
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _write_slot_cache(self, slot: int, cache1) -> None:
        """Write a batch-1 cache pytree into row ``slot`` of the batched
        caches.  Every cache leaf is (repeat, B, ...) — layer-stacked with
        the batch on axis 1 (pos counters are (repeat, B)) — so the merge is
        one dynamic_update_slice per leaf at (0, slot, 0, ...)."""
        def merge(big, one):
            if not isinstance(big, jnp.ndarray):
                return big
            assert one.shape[1] == 1 and big.shape[0] == one.shape[0], (
                f"cache leaf shapes {big.shape} vs {one.shape}")
            idx = (0, slot) + (0,) * (big.ndim - 2)
            return jax.lax.dynamic_update_slice(big, one.astype(big.dtype), idx)

        self.caches = jax.tree.map(merge, self.caches, cache1)

    def _admit(self, slot: int, req: Request,
               finished: list[Request] | None = None) -> None:
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        last_logits, cache1 = self._prefill1(self.params, batch, S_cap=self.S)
        self._write_slot_cache(slot, cache1)
        nxt = int(jnp.argmax(last_logits[0]))
        req.out.append(nxt)
        if nxt == req.eos_id or len(req.out) >= req.max_new:
            req.done = True              # prompt-only request: done at
            if finished is not None:     # admission, the slot stays free
                finished.append(req)     # (same semantics as LCSMServer).
            return
        self.tokens = self.tokens.at[slot, 0].set(nxt)
        self.slots[slot] = req

    def _fill_free_slots(self, finished: list[Request]) -> None:
        for slot in range(self.B):
            while self.slots[slot] is None and self.queue:
                self._admit(slot, self.queue.pop(0), finished)

    def admit(self, req: Request, *, rows=None, first_token=None,
              finished: list[Request] | None = None) -> int | None:
        """Frontend admission hook (surface parity with LCSMServer.admit):
        admit ``req`` into the first free slot now, bypassing the queue.
        Returns the slot used — also for requests that complete at
        admission (collected in ``finished``, slot left free) — or None
        when every slot is busy.  Transformer caches grow with the
        sequence, so there is no prefix-state restore path here —
        ``rows`` is rejected (the frontend's prefix cache is an
        LCSM/generic-engine feature; see ISSUE motivation)."""
        assert rows is None and first_token is None, (
            "prefix-state restore is only supported by the LCSM/generic "
            "backends (fixed-size sliceable slot rows)")
        for slot in range(self.B):
            if self.slots[slot] is None:
                self._admit(slot, req, finished)
                return slot
        return None

    # ----------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """Advance every active slot one token; returns requests finished
        this step (including any finished at admission)."""
        finished: list[Request] = []
        self._fill_free_slots(finished)
        if all(s is None for s in self.slots):
            return finished
        logits, self.caches = self._decode(self.params, self.tokens, self.caches)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        new_tok = np.asarray(self.tokens).copy()
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out.append(tok)
            new_tok[slot, 0] = tok
            if tok == req.eos_id or len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                self.slots[slot] = None
        self.tokens = jnp.asarray(new_tok)
        return finished

    def run(self, chunk: int | None = None) -> list[Request]:
        """Drain queue + slots to completion.  ``chunk`` is accepted only
        for surface parity with LCSMServer.run (callers can pass it
        regardless of backend family) and is IGNORED: transformer decode
        has no fused multi-token step, every token needs its own
        decode_step dispatch."""
        del chunk  # single-token decode_step either way
        done: list[Request] = []
        while self.queue or any(s is not None for s in self.slots):
            done.extend(self.step())
        return done
