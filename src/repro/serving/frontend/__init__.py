"""Serving frontend: traffic scheduling, streaming delivery, prefix-state
caching, and latency telemetry over the slot servers.

The backends under ``repro.serving`` decode; this package serves.  See
``scheduler.py`` (deterministic event-driven admission + streaming),
``prefix_cache.py`` (content-addressed post-prefill row snapshots), and
``metrics.py`` (TTFT / per-token latency / queue & occupancy telemetry).

    from repro.serving import make_server
    from repro.serving.frontend import TrafficScheduler, PrefixCache

    srv = make_server(cfg, params, n_slots=4, prompt_max=8, gen_max=32)
    sched = TrafficScheduler(srv, policy="fcfs",
                             prefix_cache=PrefixCache(byte_budget=1 << 24))
    report = sched.run(trace)          # or: for ev in sched.serve(trace): ...

``make_frontend`` builds the whole stack in one call (what
``launch/serve.py --traffic`` and ``make_server(frontend=...)`` use).
"""

from __future__ import annotations

from repro.serving.frontend.metrics import ServingMetrics  # noqa: F401
from repro.serving.frontend.prefix_cache import (  # noqa: F401
    CacheEntry, PrefixCache, prefix_key)
from repro.serving.frontend.scheduler import (  # noqa: F401
    POLICIES, StreamEvent, TrafficReport, TrafficRequest, TrafficScheduler,
    poisson_trace)


def make_frontend(server, *, policy: str = "fcfs",
                  queue_limit: int | None = None,
                  prefix_cache_bytes: int | None = None,
                  prefix_cache: bool = False,
                  prefix_cache_spill_bytes: int | None = None,
                  chunk: int | None = None):
    """Wrap a slot server in a TrafficScheduler — or a ReplicaSet in the
    replica-routing :class:`~repro.serving.frontend.replicas.ReplicaScheduler`
    (same ``serve()/run()`` surface, per-replica admission).

    ``prefix_cache=True`` (or a non-None ``prefix_cache_bytes`` byte
    budget) attaches a :class:`PrefixCache` — LCSM/GLA backends only;
    entries stay device-resident, ``prefix_cache_spill_bytes`` adds the
    host spill tier for evictions.  ``chunk`` overrides the decode
    granularity (K-token fused chunks where the backend supports them)."""
    cache = None
    if (prefix_cache or prefix_cache_bytes is not None
            or prefix_cache_spill_bytes is not None):
        cache = PrefixCache(byte_budget=prefix_cache_bytes,
                            spill_budget=prefix_cache_spill_bytes)
    from repro.serving.frontend.replicas import ReplicaScheduler, ReplicaSet
    if isinstance(server, ReplicaSet):
        return ReplicaScheduler(server, policy=policy,
                                queue_limit=queue_limit,
                                prefix_cache=cache, chunk=chunk)
    return TrafficScheduler(server, policy=policy, queue_limit=queue_limit,
                            prefix_cache=cache, chunk=chunk)
