"""Deterministic event-driven traffic scheduler with streaming delivery.

The slot servers (LCSMServer / GenericServer / ServingEngine) know how to
*decode*: admit a request into a slot, advance all slots, retire at
EOS/max_new.  This module adds the traffic layer the ROADMAP's
"heavy traffic" goal needs on top of them:

* **timed arrivals** — requests carry an ``arrival`` time on a virtual
  clock measured in decode steps; the scheduler only sees a request once
  the clock reaches it (open-loop load, reproducible run to run);
* **admission policies** — ``"fcfs"`` (arrival order) or ``"spf"``
  (shortest-prompt-first, a cheap SJF proxy: admission cost is the
  prefill, which scales with prompt length);
* **backpressure** — a bounded frontend queue: after each tick's
  admissions, arrivals that would leave more than ``queue_limit``
  requests WAITING are REJECTED, newest first (marked on the request,
  counted in metrics) instead of growing the queue without bound — an
  arrival can always take a free slot, so ``queue_limit=0`` means
  "serve immediately or reject";
* **streaming delivery** — tokens leave the system as they are produced
  (per step, or per K-token chunk under chunked decode), via per-request
  ``on_token`` callbacks and/or the ``serve()`` event iterator — not as
  end-of-run result lists;
* **prefix-state cache** — on admission the full prompt is looked up in a
  content-addressed :class:`~repro.serving.frontend.prefix_cache.PrefixCache`;
  a hit restores the snapshotted post-prefill rows into the slot (row
  copy, no prefill) and replays the cached first token, bitwise identical
  to a cold admission for greedy models; a miss prefills and inserts the
  new snapshot;
* **latency telemetry** — every lifecycle event lands in a
  :class:`~repro.serving.frontend.metrics.ServingMetrics` (TTFT,
  inter-token gaps, tok/s, queue depth, slot occupancy).

Determinism: the virtual clock advances exactly one step per server step
(K per fused chunk), idle periods fast-forward to the next arrival, and
ties break by submission order — so the same trace against the same
scheduler config produces the same admissions, the same streams, and the
same step-based metrics, every run.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import numpy as np

from repro.obs import trace as _obs
from repro.serving.engine import Request
from repro.serving.frontend.metrics import ServingMetrics
from repro.serving.frontend.prefix_cache import PrefixCache, prefix_key

POLICIES = ("fcfs", "spf")


@dataclass
class TrafficRequest:
    """A served request plus its traffic envelope."""

    req: Request
    arrival: float = 0.0  # virtual time (decode steps) the request appears
    on_token: Callable[[int, int], Any] | None = None  # (token, index)
    rejected: bool = False
    cache_hit: bool = False


@dataclass
class StreamEvent:
    """One delivered token (what ``serve()`` yields)."""

    uid: int
    index: int   # position in the request's output stream
    token: int
    step: float  # virtual time of delivery
    done: bool   # True on the request's final token


@dataclass
class TrafficReport:
    """What a ``run()`` hands back: the trace (each ``TrafficRequest.req.out``
    holds its stream), the metrics snapshot, and cache stats (or None)."""

    trace: list[TrafficRequest]
    metrics: dict
    cache: dict | None = None
    rejected_uids: list[int] = field(default_factory=list)


class TrafficScheduler:
    """Event-driven request admission over one slot server (module doc)."""

    def __init__(self, server, *, policy: str = "fcfs",
                 queue_limit: int | None = None,
                 prefix_cache: PrefixCache | None = None,
                 chunk: int | None = None,
                 metrics: ServingMetrics | None = None):
        assert policy in POLICIES, f"policy must be one of {POLICIES}"
        if prefix_cache is not None:
            assert hasattr(server, "export_slot"), (
                "prefix-state caching needs an LCSM/generic backend "
                "(fixed-size exportable slot rows); the transformer "
                "ServingEngine has a growing KV cache")
        self.server = server
        self.policy = policy
        self.queue_limit = queue_limit
        self.cache = prefix_cache
        # decode granularity: explicit chunk > the server's own default
        # (LCSMServer.chunk) > per-step.  ServingEngine has no fused
        # multi-token step, so it always runs per-step.
        k = chunk if chunk is not None else getattr(server, "chunk", None)
        self.chunk = k if (k and k > 1 and hasattr(server, "step_chunk")) else 1
        self.metrics = metrics if metrics is not None else ServingMetrics()

    # ------------------------------------------------------------ policies
    def _pick(self, pending: list[TrafficRequest]) -> int:
        if self.policy == "spf":
            return min(range(len(pending)),
                       key=lambda i: (len(pending[i].req.prompt), i))
        return 0  # fcfs: pending is kept in arrival order

    # ------------------------------------------------------------- serving
    def serve(self, trace: list[TrafficRequest]) -> Iterator[StreamEvent]:
        """Drive ``trace`` to completion, yielding every token as a
        :class:`StreamEvent` the moment it is delivered.  ``run()`` is the
        collect-everything wrapper; iterate this directly for streaming
        consumption."""
        srv, met = self.server, self.metrics
        rec = _obs.RECORDER
        sub_wall: dict[int, float] = {}  # uid -> submit wall (tracing only)
        order = sorted(range(len(trace)), key=lambda i: (trace[i].arrival, i))
        arrivals = [trace[i] for i in order]
        pending: list[TrafficRequest] = []
        live: dict[int, TrafficRequest] = {}       # uid -> in-flight
        delivered: dict[int, int] = {}             # uid -> tokens streamed
        t = 0.0
        i = 0

        def deliver(tr: TrafficRequest, done_now: bool):
            uid = tr.req.uid
            out = tr.req.out
            n0 = delivered.get(uid, 0)
            met.on_tokens(uid, len(out) - n0, int(t))
            for j in range(n0, len(out)):
                last = done_now and j == len(out) - 1
                if tr.on_token is not None:
                    tr.on_token(out[j], j)
                yield StreamEvent(uid=uid, index=j, token=out[j],
                                  step=t, done=last)
            delivered[uid] = len(out)

        def finish(tr: TrafficRequest):
            live.pop(tr.req.uid, None)
            met.on_finish(tr.req.uid, int(t))

        while i < len(arrivals) or pending or live:
            # 1) arrivals whose time has come enter the frontend queue.
            while i < len(arrivals) and arrivals[i].arrival <= t:
                tr = arrivals[i]
                i += 1
                pending.append(tr)
                met.on_submit(tr.req.uid, int(t))
                if rec is not None:
                    sub_wall[tr.req.uid] = _obs.perf_now()

            # 2) admission: fill free slots in policy order (a prefix-cache
            #    hit restores rows instead of prefilling).
            while pending and any(s is None for s in srv.slots):
                tr = pending.pop(self._pick(pending))
                entry = key = None
                if self.cache is not None:
                    key = prefix_key(tr.req.prompt, srv.engine.Lbuf)
                    entry = self.cache.lookup(key)
                if entry is not None:
                    tr.cache_hit = True
                    slot = srv.admit(tr.req, rows=entry.rows,
                                     first_token=entry.first_token)
                else:
                    slot = srv.admit(tr.req)
                    if self.cache is not None and slot is not None:
                        self.cache.insert(key, srv.export_slot(slot),
                                          tr.req.out[0], len(tr.req.prompt))
                if slot is None:  # defensive: backend reported no free slot
                    pending.insert(0, tr)
                    break
                met.on_admit(tr.req.uid, int(t), cache_hit=tr.cache_hit)
                if rec is not None:
                    now = _obs.perf_now()
                    rec.add_span("frontend.queue_wait", "frontend",
                                 sub_wall.pop(tr.req.uid, now), now,
                                 {"uid": tr.req.uid,
                                  "cache_hit": tr.cache_hit})
                    rec.inc_counter("frontend_admitted_total",
                                    cache_hit=str(tr.cache_hit).lower())
                done_now = tr.req.done
                yield from deliver(tr, done_now)  # first (prefill) token
                if done_now:
                    finish(tr)
                else:
                    live[tr.req.uid] = tr

            # 3) backpressure AFTER admission: an arrival may always take a
            #    free slot; only what must actually WAIT is held to the
            #    queue bound, and overflow (newest arrivals first) is
            #    rejected — so queue_limit=0 means "serve or reject now".
            if self.queue_limit is not None:
                while len(pending) > self.queue_limit:
                    tr = pending.pop()
                    tr.rejected = True  # never served; req.out stays empty
                    met.on_reject(tr.req.uid, int(t))
                    if rec is not None:
                        sub_wall.pop(tr.req.uid, None)
                        rec.add_instant("frontend.reject", "frontend",
                                        _obs.perf_now(), {"uid": tr.req.uid})
                        rec.inc_counter("frontend_rejected_total")

            met.on_step(int(t), queue_depth=len(pending),
                        n_live=len(live), n_slots=srv.B)
            if rec is not None:
                now = _obs.perf_now()
                rec.add_sample("frontend.queue_depth", now, len(pending))
                rec.add_sample("frontend.live_requests", now, len(live))

            # 3) advance the decode, or fast-forward an idle system to the
            #    next arrival.
            if live:
                finished = (srv.step_chunk(self.chunk) if self.chunk > 1
                            else srv.step())
                t += self.chunk
                done_uids = {r.uid for r in finished}
                for tr in list(live.values()):
                    yield from deliver(tr, tr.req.uid in done_uids)
                for uid in done_uids:
                    if uid in live:
                        finish(live[uid])
            elif not pending:
                if i >= len(arrivals):
                    break
                t = max(t, arrivals[i].arrival)
            else:  # pending but no free-slot progress possible without a step
                # (cannot happen: a pending request with every slot idle is
                # admitted above; defensive clock bump keeps us live-lock
                # free if a backend ever reports no free slot while idle)
                t += 1

    def run(self, trace: list[TrafficRequest]) -> TrafficReport:
        """Drain ``trace`` and return the collected report (streams live on
        each ``TrafficRequest.req.out``; callbacks have already fired)."""
        for _ in self.serve(trace):
            pass
        return TrafficReport(
            trace=trace,
            metrics=self.metrics.snapshot(),
            cache=self.cache.stats() if self.cache is not None else None,
            rejected_uids=[tr.req.uid for tr in trace if tr.rejected])


# ----------------------------------------------------------- trace synthesis
def poisson_trace(vocab: int, n_requests: int, *, rate: float,
                  prompt_max: int, gen_max: int, hit_frac: float = 0.0,
                  n_shared: int = 2, seed: int = 0,
                  uid_base: int = 0) -> list[TrafficRequest]:
    """Seeded open-loop request trace: Poisson-style arrivals (exponential
    inter-arrival gaps with mean ``1/rate`` steps), prompt lengths uniform
    in [1, prompt_max], outputs in [gen_max/2, gen_max].  A ``hit_frac``
    share of requests reuses one of ``n_shared`` fixed "system prompts"
    (full-prompt reuse — what the exact-match prefix cache serves); the
    rest draw unique prompts.  Deterministic per seed."""
    rng = np.random.RandomState(seed)
    shared = [rng.randint(0, vocab, (int(rng.randint(1, prompt_max + 1)),)
                          ).astype(np.int32) for _ in range(max(n_shared, 1))]
    out: list[TrafficRequest] = []
    t = 0.0
    for k in range(n_requests):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        if rng.rand() < hit_frac:
            prompt = shared[int(rng.randint(len(shared)))]
        else:
            plen = int(rng.randint(1, prompt_max + 1))
            prompt = rng.randint(0, vocab, (plen,)).astype(np.int32)
        out.append(TrafficRequest(
            req=Request(uid=uid_base + k, prompt=prompt,
                        max_new=int(rng.randint(max(gen_max // 2, 1),
                                                gen_max + 1))),
            arrival=t))
    return out
