"""Replica-parallel serving: data parallelism at the frontend, no
collectives.

The sharded server (``mesh=``) splits ONE engine's slots across devices —
every dispatch involves every device, so each program's launch latency is
paid by the whole fleet and any cross-device sync gates all slots.  The
replica mode here is the other end of the design space, and it cannot
lose: ``--replicas N`` builds N fully INDEPENDENT single-device servers
(params replicated by ``jax.device_put`` onto each device, every jitted
program compiled for and resident on its own device), and requests are
routed across them at the frontend.  No collectives, no shared state, no
cross-device predicates: each replica is exactly the single-device server,
so per-request greedy streams are bitwise identical to serving the same
request on one device — replication can only add throughput.

Two driving surfaces:

* :class:`ReplicaSet` — the backend-shaped half: ``submit()`` routes each
  request to the least-loaded replica (deterministic: ties break by
  replica index) and ``run()`` drains all replicas with DISPATCH-AHEAD
  interleaving — every replica's next fused chunk is dispatched (jax
  async dispatch) before ANY replica's previous chunk is read back, so
  all devices compute while the host does one round of readbacks.

* :class:`ReplicaScheduler` — the traffic-frontend half (what
  ``make_frontend`` returns for a ReplicaSet): the trace is split
  round-robin in arrival order across per-replica
  :class:`~repro.serving.frontend.scheduler.TrafficScheduler` instances
  whose ``serve()`` generators are interleaved one virtual-clock tick at
  a time — streaming delivery, per-replica admission/backpressure, and a
  merged report.  An optional shared :class:`PrefixCache` is wrapped per
  replica so a prefix prefilled on replica A restores on replica B (the
  rows are ``jax.device_put`` across at lookup — the only cross-device
  traffic in the whole mode).
"""

from __future__ import annotations

from typing import Any, Iterator

import jax

from repro.obs import trace as _obs
from repro.serving.engine import Request
from repro.serving.frontend.prefix_cache import CacheEntry, PrefixCache


class ReplicaSet:
    """N independent per-device servers behind one ``submit()/run()``
    surface (module doc).  ``n_slots`` is PER REPLICA (total concurrency
    is ``replicas * n_slots``); every other kwarg is forwarded to each
    member's backend constructor."""

    def __init__(self, cfg, params: Any, *, replicas: int,
                 devices=None, **server_kw):
        if server_kw.get("mesh") is not None:
            raise ValueError(
                "replicas=N and mesh= are mutually exclusive: replica mode "
                "IS the data-parallel layout (independent per-device "
                "engines); use one or the other")
        server_kw.pop("mesh", None)
        if devices is None:
            devices = jax.devices()
        if replicas < 1 or replicas > len(devices):
            raise ValueError(
                f"replicas={replicas} needs 1..{len(devices)} of the "
                f"visible {len(devices)} device(s)")
        from repro.serving import make_server  # lazy: avoids import cycle

        self.cfg = cfg
        self.devices = list(devices[:replicas])
        self.members = []
        for dev in self.devices:
            # Commit the (shared, host-built) params onto this replica's
            # device and construct under default_device so every buffer
            # and compiled program the member ever creates lives there.
            p = jax.device_put(params, dev)
            with jax.default_device(dev):
                self.members.append(make_server(cfg, p, **server_kw))

    # ------------------------------------------------------------- routing
    def _load(self, m) -> int:
        return len(m.queue) + sum(1 for s in m.slots if s is not None)

    def submit(self, req: Request) -> int:
        """Route to the least-loaded replica (ties: lowest index —
        deterministic for a fixed submission order).  Returns the replica
        index chosen."""
        i = min(range(len(self.members)),
                key=lambda j: (self._load(self.members[j]), j))
        self.members[i].submit(req)
        rec = _obs.RECORDER
        if rec is not None:
            rec.inc_counter("frontend_replica_routed_total", replica=i)
        return i

    # ------------------------------------------------------------- serving
    @property
    def chunk(self):
        return self.members[0].chunk

    @property
    def dispatch_count(self) -> int:
        return sum(m.engine.dispatch_count for m in self.members)

    def run(self, chunk: int | None = None, *,
            pipeline: bool = True) -> list[Request]:
        """Drain every replica.  Chunked runs interleave DISPATCH-AHEAD
        across replicas: one round dispatches the next fused chunk on
        every replica that has work (async — the host does not wait), the
        next loop iteration collects each replica's PREVIOUS chunk, so all
        N devices decode concurrently while the host sweeps readbacks.
        Per-step runs interleave ``step()`` round-robin."""
        K = self.chunk if chunk is None else chunk
        done: list[Request] = []
        if K is None or K <= 1 or not pipeline:
            # round-robin per-step (or strictly alternating chunk) drain
            busy = True
            while busy:
                busy = False
                for m in self.members:
                    if m.queue or any(s is not None for s in m.slots):
                        done.extend(m.step() if K is None or K <= 1
                                    else m.step_chunk(K))
                        busy = True
            return done
        pends: list[tuple | None] = [None] * len(self.members)
        while True:
            nxts: list[tuple | None] = []
            for m in self.members:           # dispatch round: all async
                fin, nxt = m.dispatch_chunk(K)
                done.extend(fin)
                nxts.append(nxt)
            for m, pend in zip(self.members, pends):  # collect round
                if pend is not None:
                    done.extend(m.collect_chunk(pend))
            pends = nxts
            if all(p is None for p in pends):
                return done


class _ReplicaCacheView:
    """One replica's view of a shared :class:`PrefixCache`: lookups whose
    rows live on another replica's device are ``jax.device_put`` across
    before the member imports them (mixed committed devices would
    otherwise fault inside the jitted row splice).  Inserts pass through —
    the stored rows stay resident wherever the exporting replica put
    them."""

    def __init__(self, cache: PrefixCache, device):
        self._cache = cache
        self._device = device

    def lookup(self, key: str) -> CacheEntry | None:
        e = self._cache.lookup(key)
        if e is None:
            return None
        leaves = jax.tree.leaves(e.rows)
        if leaves and all(hasattr(leaf, "devices")
                          and leaf.devices() == {self._device}
                          for leaf in leaves):
            return e  # already resident here (the common same-replica hit)
        return CacheEntry(rows=jax.device_put(e.rows, self._device),
                          first_token=e.first_token, plen=e.plen,
                          nbytes=e.nbytes)

    def insert(self, key: str, rows, first_token: int, plen: int) -> bool:
        return self._cache.insert(key, rows, first_token, plen)

    def stats(self) -> dict:
        return self._cache.stats()


class ReplicaScheduler:
    """Traffic frontend over a :class:`ReplicaSet` (module doc): the same
    ``serve()/run()`` surface as TrafficScheduler, implemented by routing
    the trace round-robin (in arrival order) across one per-replica
    TrafficScheduler and interleaving their event streams one scheduler
    tick at a time.  ``queue_limit`` applies per replica."""

    def __init__(self, replica_set: ReplicaSet, *, policy: str = "fcfs",
                 queue_limit: int | None = None,
                 prefix_cache: PrefixCache | None = None,
                 chunk: int | None = None):
        from repro.serving.frontend.scheduler import TrafficScheduler

        self.server = replica_set
        self.cache = prefix_cache
        self.members = [
            TrafficScheduler(
                m, policy=policy, queue_limit=queue_limit,
                prefix_cache=(None if prefix_cache is None else
                              _ReplicaCacheView(prefix_cache, dev)),
                chunk=chunk)
            for m, dev in zip(replica_set.members, replica_set.devices)]

    def _shard_trace(self, trace):
        order = sorted(range(len(trace)),
                       key=lambda i: (trace[i].arrival, i))
        shards = [[] for _ in self.members]
        for k, i in enumerate(order):
            shards[k % len(self.members)].append(trace[i])
        return shards

    def serve(self, trace) -> Iterator:
        """Round-robin interleaving of the per-replica ``serve()``
        generators: each turn advances one replica by one event.  Requests
        are pre-routed round-robin in arrival order — deterministic for a
        fixed trace, independent of decode timing."""
        gens = [m.serve(shard)
                for m, shard in zip(self.members, self._shard_trace(trace))]
        active = list(gens)
        while active:
            still = []
            for g in active:
                try:
                    yield next(g)
                    still.append(g)
                except StopIteration:
                    pass
            active = still

    def metrics_snapshot(self) -> dict:
        """Per-replica metric snapshots plus fleet totals (JSON-ready)."""
        snaps = [m.metrics.snapshot() for m in self.members]
        obs = None
        for s in snaps:
            s.pop("per_request", None)
            # One recorder serves the whole process: every member snapshot
            # would repeat the identical flashtrace rollup — hoist it.
            obs = s.pop("obs", obs)
        tokens = sum(s["throughput"]["tokens"] for s in snaps)
        wall = max((s["throughput"]["wall_s"] for s in snaps), default=0.0)
        out = {
            "replicas": snaps,
            "n_replicas": len(self.members),
            "throughput": {"tokens": tokens, "wall_s": wall,
                           "tok_s": tokens / wall if wall > 0 else 0.0},
        }
        if obs is not None:
            out["obs"] = obs
        return out

    def run(self, trace):
        """Drain ``trace``; returns a TrafficReport whose metrics dict
        carries per-replica snapshots plus fleet totals."""
        from repro.serving.frontend.scheduler import TrafficReport

        for _ in self.serve(trace):
            pass
        return TrafficReport(
            trace=trace,
            metrics=self.metrics_snapshot(),
            cache=self.cache.stats() if self.cache is not None else None,
            rejected_uids=[tr.req.uid for tr in trace if tr.rejected])
