"""Latency telemetry for the serving frontend.

One :class:`ServingMetrics` instance rides along a scheduler run and
records the request lifecycle (submit -> admit -> first token -> finish)
plus per-step gauges (queue depth, slot occupancy).  Every event carries
TWO clocks:

* ``step``  — the scheduler's deterministic virtual clock (decode steps):
  identical across runs of the same trace, so tests can pin step-based
  latencies exactly;
* ``wall``  — ``time.perf_counter()`` seconds: the real latency numbers
  the benchmark reports (TTFT, per-token latency, tok/s).

``snapshot()`` folds the raw timelines into one structured, JSON-ready
dict — the record benchmarks/bench_traffic.py emits per series cell.
"""

from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field

from repro.obs import trace as _obs


@dataclass
class RequestTimeline:
    """Lifecycle timestamps of one request (both clocks; -1 = never)."""

    uid: int
    submit_step: int = -1
    submit_wall: float = -1.0
    admit_step: int = -1
    admit_wall: float = -1.0
    first_token_step: int = -1
    first_token_wall: float = -1.0
    finish_step: int = -1
    finish_wall: float = -1.0
    n_tokens: int = 0
    cache_hit: bool = False
    rejected: bool = False
    token_walls: list[float] = field(default_factory=list)


def _percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile without numpy (tiny lists, exact ranks)."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[i]


def _dist(xs: list[float]) -> dict:
    if not xs:
        return {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0, "max": 0.0}
    return {
        "n": len(xs),
        "mean": sum(xs) / len(xs),
        "p50": _percentile(xs, 50),
        "p95": _percentile(xs, 95),
        "max": max(xs),
    }


class ServingMetrics:
    """Event sink for TrafficScheduler (see module docstring)."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self.timelines: dict[int, RequestTimeline] = {}
        self.queue_depths: list[int] = []   # sampled once per scheduler step
        self.occupancies: list[float] = []  # live slots / total slots
        self.n_steps = 0
        self.n_tokens = 0
        self.n_cache_hits = 0
        self.n_cache_misses = 0
        # First/last event walls: throughput is measured over the span the
        # system was actually serving, not since this object was built —
        # idle time between construction and the first submit must not
        # deflate tok/s (a metrics object created early, e.g. at process
        # start, would otherwise report arbitrarily low throughput).
        self._first_event_wall: float | None = None
        self._last_event_wall: float | None = None

    def _wall(self) -> float:
        """Event timestamp; every call widens the first->last event span
        snapshot() measures throughput over."""
        w = self._clock() - self._t0
        if self._first_event_wall is None:
            self._first_event_wall = w
        self._last_event_wall = w
        return w

    def _tl(self, uid: int) -> RequestTimeline:
        if uid not in self.timelines:
            self.timelines[uid] = RequestTimeline(uid=uid)
        return self.timelines[uid]

    # ----------------------------------------------------- lifecycle events
    def on_submit(self, uid: int, step: int) -> None:
        tl = self._tl(uid)
        tl.submit_step, tl.submit_wall = step, self._wall()

    def on_reject(self, uid: int, step: int) -> None:
        tl = self._tl(uid)
        if tl.submit_step < 0:
            tl.submit_step, tl.submit_wall = step, self._wall()
        tl.rejected = True

    def on_admit(self, uid: int, step: int, cache_hit: bool) -> None:
        tl = self._tl(uid)
        tl.admit_step, tl.admit_wall = step, self._wall()
        tl.cache_hit = cache_hit
        if cache_hit:
            self.n_cache_hits += 1
        else:
            self.n_cache_misses += 1

    def on_tokens(self, uid: int, n_new: int, step: int) -> None:
        """``n_new`` tokens just streamed for ``uid`` (first call of a
        request also stamps its first-token time = TTFT)."""
        if n_new <= 0:
            return
        tl = self._tl(uid)
        wall = self._wall()
        if tl.first_token_step < 0:
            tl.first_token_step, tl.first_token_wall = step, wall
        tl.token_walls.extend([wall] * n_new)
        tl.n_tokens += n_new
        self.n_tokens += n_new

    def on_finish(self, uid: int, step: int) -> None:
        tl = self._tl(uid)
        tl.finish_step, tl.finish_wall = step, self._wall()

    def on_step(self, step: int, queue_depth: int, n_live: int,
                n_slots: int) -> None:
        self.n_steps = max(self.n_steps, step)
        self.queue_depths.append(queue_depth)
        self.occupancies.append(n_live / max(n_slots, 1))

    # -------------------------------------------------------------- rollup
    def snapshot(self) -> dict:
        """Structured aggregate view (JSON-ready).  Wall-clock fields vary
        run to run; every ``*_steps`` field is deterministic for a fixed
        trace/scheduler config."""
        tls = list(self.timelines.values())
        done = [t for t in tls if t.finish_step >= 0]
        ttft_wall = [t.first_token_wall - t.submit_wall
                     for t in tls if t.first_token_step >= 0]
        ttft_steps = [float(t.first_token_step - t.submit_step)
                      for t in tls if t.first_token_step >= 0]
        # inter-token gaps within each stream (the "per-token latency" a
        # streaming client sees between consecutive deliveries)
        gaps: list[float] = []
        for t in tls:
            gaps.extend(b - a for a, b in zip(t.token_walls, t.token_walls[1:]))
        # First-event -> last-event span (NOT time since construction, and
        # snapshot() itself is not an event): see __init__.
        wall = (self._last_event_wall - self._first_event_wall
                if self._first_event_wall is not None else 0.0)
        out = {
            "requests": {
                "submitted": len(tls),
                "admitted": sum(1 for t in tls if t.admit_step >= 0),
                "completed": len(done),
                "rejected": sum(1 for t in tls if t.rejected),
                "cache_hits": self.n_cache_hits,
                "cache_misses": self.n_cache_misses,
            },
            "ttft_s": _dist(ttft_wall),
            "ttft_steps": _dist(ttft_steps),
            "token_gap_s": _dist(gaps),
            "throughput": {
                "tokens": self.n_tokens,
                "wall_s": wall,
                "tok_s": self.n_tokens / wall if wall > 0 else 0.0,
            },
            "queue_depth": _dist([float(q) for q in self.queue_depths]),
            "slot_occupancy": _dist(self.occupancies),
            "steps": self.n_steps,
            "per_request": [asdict(t) | {"token_walls": None} for t in
                            sorted(tls, key=lambda t: t.uid)],
        }
        rec = _obs.RECORDER
        if rec is not None:
            # Flashtrace rollup rides along when tracing is on: counters +
            # gauges only (spans go to the Perfetto export, not JSON).
            out["obs"] = {"counters": rec.counters_view(),
                          "gauges": rec.gauges_view(),
                          "dropped": rec.dropped}
        return out
