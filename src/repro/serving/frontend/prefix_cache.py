"""Content-addressed prefix-state cache for LCSM/generic-engine serving.

The whole inference state of a slot after ingesting a prefix is its
fixed-size buffer rows (unlike attention's growing KV cache, they are
sliceable and constant-shape — the serving-side payoff of the paper's
recurrence view).  So a shared system prompt can be prefilled ONCE, its
post-prefill rows exported (``ScheduleWalker.export_slot_rows``), and
every later request with the same token prefix admitted by a row copy
(``import_slot_rows``) — skipping prefill entirely while staying bitwise
identical to a cold admission: the restored rows ARE the rows the
prefill wrote, and the server splits its rng identically on both paths.

Keys are content addresses: the SHA-1 of the prompt's int32 token bytes
(plus the engine's buffer horizon, so caches can't leak across engines
with different Lbuf — Hyena's length-normalized filters make a different
Lbuf a different model).  Lookup is EXACT-match over the full prompt:
restoring a *proper* prefix and re-ingesting the suffix would need an
incremental prefill whose rounding differs from the one-shot FFT path,
breaking the bitwise guarantee this cache exists to keep.

Eviction is LRU under a byte budget over the stored rows (host copies —
``jax.device_get`` — so entries survive the engine donating its state
buffers in place).

Caveat (same as chunked serving's rng note): the cached first token and
rows replay exactly for greedy models, whose ``advance`` ignores its rng.
A model that truly samples its first token would see an equally valid but
different draw than a cold prefill with the admission's fresh sub-key.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np


def prefix_key(tokens, horizon: int) -> str:
    """Content address of a token prefix for an engine with buffer horizon
    ``horizon`` (= Lbuf)."""
    arr = np.ascontiguousarray(np.asarray(tokens, np.int32))
    h = hashlib.sha1()
    h.update(str(int(horizon)).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


@dataclass
class CacheEntry:
    rows: Any          # batch-1 state pytree, host (numpy) leaves
    first_token: int   # the prefill-advance token to replay
    plen: int          # prefix length (bookkeeping/debug)
    nbytes: int


class PrefixCache:
    """LRU map: content address -> post-prefill slot rows + first token.

    ``byte_budget`` bounds the total stored row bytes (None = unbounded).
    An entry larger than the whole budget is simply not stored.  Hit/miss/
    eviction counters feed the frontend's metrics snapshot.
    """

    def __init__(self, byte_budget: int | None = None):
        self.byte_budget = byte_budget
        self._entries: OrderedDict[str, CacheEntry] = OrderedDict()
        self.nbytes = 0
        self.hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def lookup(self, key: str) -> CacheEntry | None:
        """LRU-touching lookup; counts a hit or miss."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return e

    def insert(self, key: str, rows, first_token: int, plen: int) -> bool:
        """Store exported slot rows under ``key`` (host copies), evicting
        LRU entries past the byte budget.  Returns False when the entry
        alone exceeds the budget (nothing stored)."""
        if key in self._entries:  # refresh recency, keep the existing copy
            self._entries.move_to_end(key)
            return True
        rows = jax.device_get(rows)  # host copy: donation-proof, countable
        nbytes = sum(leaf.nbytes for leaf in jax.tree.leaves(rows))
        if self.byte_budget is not None and nbytes > self.byte_budget:
            return False
        self._entries[key] = CacheEntry(rows=rows, first_token=int(first_token),
                                        plen=plen, nbytes=nbytes)
        self.nbytes += nbytes
        self.insertions += 1
        while (self.byte_budget is not None
               and self.nbytes > self.byte_budget and len(self._entries) > 1):
            _, old = self._entries.popitem(last=False)
            self.nbytes -= old.nbytes
            self.evictions += 1
        return True

    def stats(self) -> dict:
        return {"entries": len(self._entries), "bytes": self.nbytes,
                "hits": self.hits, "misses": self.misses,
                "insertions": self.insertions, "evictions": self.evictions}
