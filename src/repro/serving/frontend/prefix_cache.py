"""Content-addressed prefix-state cache for LCSM/generic-engine serving.

The whole inference state of a slot after ingesting a prefix is its
fixed-size buffer rows (unlike attention's growing KV cache, they are
sliceable and constant-shape — the serving-side payoff of the paper's
recurrence view).  So a shared system prompt can be prefilled ONCE, its
post-prefill rows exported (``ScheduleWalker.export_slot_rows``), and
every later request with the same token prefix admitted by a row copy
(``import_slot_rows``) — skipping prefill entirely while staying bitwise
identical to a cold admission: the restored rows ARE the rows the
prefill wrote, and the server splits its rng identically on both paths.

Keys are content addresses: the SHA-1 of the prompt's int32 token bytes
(plus the engine's buffer horizon, so caches can't leak across engines
with different Lbuf — Hyena's length-normalized filters make a different
Lbuf a different model).  Lookup is EXACT-match over the full prompt:
restoring a *proper* prefix and re-ingesting the suffix would need an
incremental prefill whose rounding differs from the one-shot FFT path,
breaking the bitwise guarantee this cache exists to keep.

Storage is DEVICE-RESIDENT: ``export_slot_rows`` already returns fresh
buffers (a gather, not a view), so the snapshot survives the engine
donating its state in place WITHOUT a host copy — the per-miss
``jax.device_get`` an earlier revision paid here serialized every
admission on a device sync and made the cache a 2.7× slowdown at 0% hit
rate (BENCH_traffic).  Eviction is LRU under a byte budget over the
stored rows.  An optional second tier (``spill_budget``) catches evicted
entries on the HOST — ``device_get`` happens only when eviction forces
the spill, never on the admission path — and host-tier hits transfer
back on restore.

Caveat (same as chunked serving's rng note): the cached first token and
rows replay exactly for greedy models, whose ``advance`` ignores its rng.
A model that truly samples its first token would see an equally valid but
different draw than a cold prefill with the admission's fresh sub-key.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

from repro.obs import trace as _obs


def prefix_key(tokens, horizon: int) -> str:
    """Content address of a token prefix for an engine with buffer horizon
    ``horizon`` (= Lbuf)."""
    arr = np.ascontiguousarray(np.asarray(tokens, np.int32))
    h = hashlib.sha1()
    h.update(str(int(horizon)).encode())
    h.update(arr.tobytes())
    return h.hexdigest()


@dataclass
class CacheEntry:
    rows: Any          # batch-1 state pytree: device arrays (device tier)
                       # or numpy (host spill tier)
    first_token: int   # the prefill-advance token to replay
    plen: int          # prefix length (bookkeeping/debug)
    nbytes: int


class PrefixCache:
    """LRU map: content address -> post-prefill slot rows + first token.

    Entries stay DEVICE-RESIDENT (the exported rows are stored as-is: no
    host copy, no device sync on the admission path).  ``byte_budget``
    bounds the total stored row bytes (None = unbounded); an entry larger
    than the whole budget is simply not stored.  ``spill_budget`` (None =
    no spill tier) adds a host-memory second tier: entries evicted from
    the device tier are ``jax.device_get``-spilled instead of dropped —
    the ONLY place this cache ever syncs — and a spill-tier hit restores
    through the ordinary import path (jax puts the host rows back on
    device).  Hit/miss/eviction/spill counters feed the frontend's
    metrics snapshot.
    """

    def __init__(self, byte_budget: int | None = None,
                 spill_budget: int | None = None):
        self.byte_budget = byte_budget
        self.spill_budget = spill_budget
        self._entries: OrderedDict[str, CacheEntry] = OrderedDict()
        self._spill: OrderedDict[str, CacheEntry] = OrderedDict()
        self.nbytes = 0
        self.spill_nbytes = 0
        self.hits = 0
        self.spill_hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self.spills = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries or key in self._spill

    def lookup(self, key: str) -> CacheEntry | None:
        """LRU-touching lookup; counts a hit or miss.  Checks the device
        tier first, then the host spill tier (a spill hit stays in its
        tier, bumped to most-recently-used — the import path moves the
        rows back to device where they are needed)."""
        rec = _obs.RECORDER
        e = self._entries.get(key)
        if e is not None:
            self._entries.move_to_end(key)
            self.hits += 1
            if rec is not None:
                rec.inc_counter("prefix_cache_lookups_total", tier="device",
                                event="hit")
                rec.add_instant("prefix_cache.hit", "frontend",
                                _obs.perf_now(), {"tier": "device"})
            return e
        e = self._spill.get(key)
        if e is not None:
            self._spill.move_to_end(key)
            self.hits += 1
            self.spill_hits += 1
            if rec is not None:
                rec.inc_counter("prefix_cache_lookups_total", tier="spill",
                                event="hit")
                rec.add_instant("prefix_cache.hit", "frontend",
                                _obs.perf_now(), {"tier": "spill"})
            return e
        self.misses += 1
        if rec is not None:
            rec.inc_counter("prefix_cache_lookups_total", tier="none",
                            event="miss")
            rec.add_instant("prefix_cache.miss", "frontend", _obs.perf_now())
        return None

    def insert(self, key: str, rows, first_token: int, plen: int) -> bool:
        """Store exported slot rows under ``key`` AS-IS (device-resident:
        ``export_slot_rows`` returns fresh buffers, so there is no
        donation hazard and no host sync on this path), evicting LRU
        entries past the byte budget.  Evictions spill to the host tier
        when ``spill_budget`` is set, else drop.  Returns False when the
        entry alone exceeds the budget (nothing stored)."""
        if key in self._entries or key in self._spill:
            # refresh recency, keep the existing copy
            (self._entries if key in self._entries
             else self._spill).move_to_end(key)
            return True
        nbytes = sum(leaf.nbytes for leaf in jax.tree.leaves(rows))
        if self.byte_budget is not None and nbytes > self.byte_budget:
            return False
        self._entries[key] = CacheEntry(rows=rows, first_token=int(first_token),
                                        plen=plen, nbytes=nbytes)
        self.nbytes += nbytes
        self.insertions += 1
        rec = _obs.RECORDER
        if rec is not None:
            rec.inc_counter("prefix_cache_insertions_total")
        while (self.byte_budget is not None
               and self.nbytes > self.byte_budget and len(self._entries) > 1):
            old_key, old = self._entries.popitem(last=False)
            self.nbytes -= old.nbytes
            self.evictions += 1
            if rec is not None:
                rec.inc_counter("prefix_cache_evictions_total")
                rec.add_instant("prefix_cache.evict", "frontend",
                                _obs.perf_now(), {"nbytes": old.nbytes})
            if self.spill_budget is not None:
                self._spill_entry(old_key, old)
        if rec is not None:
            rec.set_gauge("prefix_cache_bytes", self.nbytes, tier="device")
            rec.set_gauge("prefix_cache_bytes", self.spill_nbytes,
                          tier="spill")
        return True

    def _spill_entry(self, key: str, e: CacheEntry) -> None:
        """Evicted from the device tier: materialize on host (the one
        forced ``device_get``) and LRU-bound the spill tier by its own
        byte budget."""
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        host = CacheEntry(rows=jax.device_get(e.rows),
                          first_token=e.first_token, plen=e.plen,
                          nbytes=e.nbytes)
        if rec is not None:
            # device_get is a forced sync — worth a span, not just a count.
            rec.add_span("prefix_cache.spill", "frontend", t0,
                         _obs.perf_now(), {"nbytes": e.nbytes})
            rec.inc_counter("prefix_cache_spills_total")
        if host.nbytes > self.spill_budget:
            return
        self._spill[key] = host
        self.spill_nbytes += host.nbytes
        self.spills += 1
        while self.spill_nbytes > self.spill_budget and len(self._spill) > 1:
            _, old = self._spill.popitem(last=False)
            self.spill_nbytes -= old.nbytes

    def stats(self) -> dict:
        return {"entries": len(self._entries), "bytes": self.nbytes,
                "spill_entries": len(self._spill),
                "spill_bytes": self.spill_nbytes,
                "hits": self.hits, "spill_hits": self.spill_hits,
                "misses": self.misses, "insertions": self.insertions,
                "evictions": self.evictions, "spills": self.spills}
