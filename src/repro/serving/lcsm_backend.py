"""Serving backend for LCSM (Hyena) architectures: continuously batched
Flash Inference decode.

Slot-based server over repro.core.engine.FlashEngine (Algorithms 2/3) with
the same ``submit()/step()/run()`` surface as the transformer-family
ServingEngine.  The engine's tile schedule is **per-slot**: each slot
carries its own ``origin`` (prompt length) and ``pos``, the red pass
advances all live slots in one jitted call with per-slot positions, and
the gray tiles every slot's schedule unlocks this step go out as ONE
batched mask-select dispatch (``ScheduleWalker.tiles_step``: every
possible side computed on the gathered per-slot rows, merged by mask —
no data-dependent branching, no per-side host round-trips).  The retired
per-(slot, tile-side) host grouping survives behind
``engine.server_dispatch = "reference"`` as the exactness reference.

Admission is vLLM-style slot refill: a finished slot (EOS or max_new) is
immediately refilled from the queue by a single-slot prefill (static FFT
path, Massaroli Lemma 2.1) that rewrites the slot's full a/b buffer rows
(``FlashEngine.prefill_slot``) — no other slot is disturbed, no recompile
(tile-side and prompt-length specializations are cached).

Decode granularities sharing the bookkeeping:

* ``step()``       — one token per host round-trip (red pass + one batched
  tile dispatch), reading tokens back every step.
* ``step_chunk(K)``— DEVICE-RESIDENT: one fused, donated XLA computation
  advances every slot K tokens (``FlashEngine.server_chunk`` drives each
  slot's own schedule through the batched tile dispatch), and the token
  readback is deferred to the chunk end — host syncs drop from O(n_tokens)
  to O(n_tokens/K).  Slots are stepped blindly through the chunk; the host
  truncates each stream at EOS/max_new afterwards, so greedy streams are
  exactly the per-step ones (overshoot work only touches rows the refill
  prefill rewrites; see step_chunk's rng caveat for sampling models).
  Retirement/admission happen at chunk boundaries.
* ``dispatch_chunk(K)`` / ``collect_chunk`` — the two halves of
  ``step_chunk`` split apart so ``run()`` can DISPATCH-AHEAD: chunk N+1
  is dispatched (jax async dispatch, donated state future) BEFORE chunk
  N's tokens are read back, overlapping host scheduling with device
  compute.  Retirement and admission lag one chunk behind the device;
  the extra blind chunk a retired slot receives only touches its own
  rows, which the refill prefill rewrites wholesale, so greedy streams
  stay exactly the per-step ones.

``generate()`` keeps the historical lockstep batch-at-once path (all rows
share one schedule position) for benchmarks and exactness tests.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import FlashEngine
from repro.core.tiling import largest_pow2_divisor
from repro.models.hyena import HyenaLCSM
from repro.obs import trace as _obs
from repro.serving.engine import Request


def isolated_decode_via(model, eng, params: Any, prompt,
                        n_tokens: int) -> list[int]:
    """Batch-1 lockstep greedy decode through an already-built
    (model, engine) pair: prefill (the first token comes from the prefill
    advance), then generate from origin = prompt length.  The ONE
    reference-decode implementation every slot-sharing exactness
    comparison is measured against — the family-specific wrappers below
    and in serving/generic_backend only choose the classes.  The prefill
    BUCKETS (pow2 prompt padding) because server admissions bucket: a
    different pad can mean a different static FFT size / λ-power split,
    i.e. different rounding, and these streams are compared bitwise."""
    a0 = model.embed_tokens(params, jnp.asarray(prompt, jnp.int32)[None])
    state, t0 = eng.prefill(a0, bucket=True)
    out = [int(t0[0])]
    if n_tokens > 1:
        _, toks = eng.generate(state, n_tokens - 1, origin=len(prompt))
        out += np.asarray(toks)[0].tolist()
    return out[:n_tokens]


def isolated_decode(cfg: ModelConfig, params: Any, prompt, n_tokens: int, *,
                    prompt_max: int, gen_max: int,
                    strategy: str = "flash") -> list[int]:
    """Isolated batch-1 lockstep greedy decode — the exactness reference for
    continuous batching (used by tests and examples/serve_batched.py).

    ``prompt_max``/``gen_max`` MUST match the server under comparison: they
    determine Lbuf, and Hyena's implicit filters are length-normalized, so a
    different Lbuf is a different model, not a numerics difference."""
    model = HyenaLCSM(cfg)
    eng = FlashEngine(model, params, batch=1, gen_max=gen_max,
                      prompt_max=prompt_max, strategy=strategy)
    return isolated_decode_via(model, eng, params, prompt, n_tokens)


class LCSMServer:
    """Continuous-batching server for ``cfg.family == "lcsm"`` archs.

    ``n_slots`` bounds concurrent requests; ``prompt_max`` / ``gen_max``
    size the per-slot buffers (Lbuf = prompt_max + ceil_pow2(gen_max)).
    ``batch`` is accepted as a legacy alias for ``n_slots``.
    """

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 n_slots: int | None = None, batch: int | None = None,
                 gen_max: int, prompt_max: int = 0,
                 strategy: str = "flash", tau_impl: str = "hybrid",
                 direct_max: int = 32, use_pallas: bool = False,
                 gray_impl: str = "xla",
                 chunk: int | None = None, chunk_size: int = 1,
                 mesh=None, seed: int = 0):
        assert cfg.family == "lcsm"
        assert strategy in ("flash", "lazy", "eager")
        if n_slots is None:
            n_slots = 1 if batch is None else batch
        self.cfg = cfg
        self.model = HyenaLCSM(cfg)
        self.params = params
        # mesh: slots shard over the 'data' axis, channels over 'model'
        # (launch/sharding.engine_state_specs); greedy streams stay bitwise
        # identical to the single-device server for the same request trace
        # (tests/test_differential.py).
        self.mesh = mesh
        self.engine = FlashEngine(
            self.model, params, batch=n_slots, gen_max=gen_max,
            prompt_max=prompt_max, strategy=strategy, tau_impl=tau_impl,
            direct_max=direct_max, use_pallas=use_pallas,
            gray_impl=gray_impl, chunk_size=chunk_size, mesh=mesh)
        self._init_slot_bookkeeping(
            n_slots, strategy=strategy, gen_max=gen_max,
            prompt_max=prompt_max, chunk=chunk, chunk_size=chunk_size,
            seed=seed)

    def _init_slot_bookkeeping(self, n_slots: int, *, strategy: str,
                               gen_max: int, prompt_max: int,
                               chunk: int | None, chunk_size: int,
                               seed: int) -> None:
        """The engine-independent tail of construction, shared with every
        subclassed backend (serving/generic_backend.GenericServer): slot
        tables, per-slot schedule positions, the run() chunk default.
        Requires ``self.engine`` to be set."""
        self.batch = self.B = n_slots
        self.strategy = strategy
        self.gen_max = gen_max
        self.prompt_max = prompt_max
        # decode granularity for run(): None/1 = per-step host loop,
        # K > 1 = fused device-resident chunks of K tokens (step_chunk).
        # One knob is enough: an engine built for chunked decode
        # (chunk_size > 1) serves chunked too unless ``chunk`` overrides.
        self.chunk = chunk if chunk is not None else (
            chunk_size if chunk_size > 1 else None)

        # --- continuous-batching state (host-side bookkeeping is plain ints)
        self.state = self.engine.init_state()
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.pos = [0] * n_slots     # next position to finalize, per slot
        self.origin = [0] * n_slots  # schedule origin (prompt length)
        self._rng = jax.random.PRNGKey(seed)

    # ------------------------------------------------------------ admission
    def _check_request(self, req: Request) -> None:
        P = len(req.prompt)
        assert 1 <= P <= max(self.prompt_max, 1), (
            f"prompt length {P} exceeds prompt_max={self.prompt_max}")
        assert 1 <= req.max_new <= self.gen_max, (
            f"max_new {req.max_new} exceeds gen_max={self.gen_max}")

    def submit(self, req: Request) -> None:
        self._check_request(req)
        self.queue.append(req)

    def _admit(self, slot: int, req: Request, finished: list[Request],
               rows=None, first_token: int | None = None) -> None:
        P = len(req.prompt)
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        # The rng is split whether the prefill runs or the rows are restored
        # from the prefix cache, so the downstream key schedule — and hence
        # every later sampled token — is identical on the hit and miss paths.
        self._rng, sub = jax.random.split(self._rng)
        if rows is None:
            a0 = self.model.embed_tokens(
                self.params, jnp.asarray(req.prompt, jnp.int32)[None])
            self.state, tok = self.engine.prefill_slot(
                self.state, slot, a0, sub)
            tok = int(tok)
        else:
            # prefix-cache hit: the post-prefill rows are spliced in and the
            # cached first token replayed — bitwise what prefill_slot would
            # produce for greedy models (advance ignores its rng; a sampling
            # model's first token would need `sub`, see frontend docs).
            self.state = self.engine.import_slot_rows(self.state, slot, rows)
            tok = int(first_token)
        if rec is not None:
            rec.add_span("server.admit", "server", t0, _obs.perf_now(),
                         {"uid": req.uid, "slot": slot, "P": P,
                          "restored": rows is not None})
            rec.inc_counter("serving_admissions_total",
                            path="restore" if rows is not None else "prefill")
        req.out.append(tok)
        if tok == req.eos_id or len(req.out) >= req.max_new:
            req.done = True          # prompt-only request: done at admission,
            finished.append(req)     # the slot stays free for the next one.
            return
        self.slots[slot] = req
        self.origin[slot] = P
        self.pos[slot] = P

    def _fill_free_slots(self, finished: list[Request]) -> None:
        for slot in range(self.B):
            while self.slots[slot] is None and self.queue:
                self._admit(slot, self.queue.pop(0), finished)

    # ------------------------------------------- frontend admission surface
    def admit(self, req: Request, *, rows=None, first_token: int | None = None,
              finished: list[Request] | None = None) -> int | None:
        """Admit ``req`` into the first free slot NOW, bypassing the queue —
        the serving frontend's admission hook (it owns request ordering, so
        it feeds slots directly instead of going through ``self.queue``).

        With ``rows``/``first_token`` (a prefix-state-cache hit, see
        serving/frontend/prefix_cache) the slot is restored by a row copy
        and prefill is skipped entirely.  Returns the slot used — also for
        requests that complete at admission (their prefilled rows remain
        exportable) — or None when every slot is busy.  ``finished``
        collects requests that complete at admission."""
        self._check_request(req)
        for slot in range(self.B):
            if self.slots[slot] is None:
                self._admit(slot, req, [] if finished is None else finished,
                            rows=rows, first_token=first_token)
                return slot
        return None

    def export_slot(self, slot: int):
        """Snapshot slot ``slot``'s full engine rows (a fresh batch-1 state
        pytree, immune to later donation) — what the prefix cache stores
        right after a cache-miss admission."""
        return self.engine.export_slot_rows(self.state, slot)

    # ----------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """Admit queued requests into free slots, then advance every live
        slot one token; returns requests finished this step."""
        finished: list[Request] = []
        self._fill_free_slots(finished)
        live = [s for s in range(self.B) if self.slots[s] is not None]
        if not live:
            return finished
        rec = _obs.RECORDER
        t_step = _obs.perf_now() if rec is not None else 0.0
        eng = self.engine
        # free slots idle at position 0: the red pass still computes their
        # rows (pure per-row ops — no cross-slot contamination), and their
        # buffers are fully rewritten by prefill_slot on reuse.
        p_vec = jnp.asarray([self.pos[s] if self.slots[s] is not None else 0
                             for s in range(self.B)], jnp.int32)
        self._rng, sub = jax.random.split(self._rng)
        if self.strategy == "lazy":
            self.state = eng.lazy_step(self.state, p_vec)
        self.state, toks = eng.red_step(self.state, p_vec, sub)
        if self.strategy == "eager":
            self.state = eng.eager_step(self.state, p_vec)
        toks = np.asarray(toks)
        mask = np.zeros((self.B,), bool)
        pv = np.zeros((self.B,), np.int32)
        for s in live:
            req = self.slots[s]
            tok = int(toks[s])
            req.out.append(tok)
            p = self.pos[s]
            self.pos[s] += 1
            if tok == req.eos_id or len(req.out) >= req.max_new:
                req.done = True
                finished.append(req)
                self.slots[s] = None  # retire; no tile — its outputs would
                continue              # only feed positions never generated.
            mask[s] = True
            pv[s] = p
        if self.strategy == "flash" and mask.any():
            if eng.server_dispatch == "reference":
                self._step_tiles_reference(mask, pv)
            else:
                # ONE batched dispatch applies every unlocked tile: the
                # engine derives each slot's side from pos/origin and
                # mask-selects (tiles_step) — no per-side host grouping.
                self.state = eng.tiles_step(
                    self.state, jnp.asarray(pv),
                    jnp.asarray(self.origin, np.int32), jnp.asarray(mask))
        if rec is not None:
            t1 = _obs.perf_now()
            rec.add_span("server.step", "server", t_step, t1,
                         {"live": len(live)})
            rec.add_sample("server.live_slots", t1, len(live))
        return finished

    def _step_tiles_reference(self, mask: np.ndarray, pv: np.ndarray) -> None:
        """The RETIRED per-(slot, tile-side) host grouping (PR 2–5 step
        path), kept as the exactness reference for the batched per-step
        dispatch: group live slots by the side their schedule unlocks,
        dispatch one masked ``gray_step`` per non-empty group — log2(L)
        host round-trips per token in the worst case."""
        eng = self.engine
        tiles: dict[int, list[tuple[int, int]]] = {}  # U -> [(slot, p)]
        for s in np.nonzero(mask)[0]:
            s = int(s)
            # red steps since origin = this slot's 1-based schedule step
            U = largest_pow2_divisor(self.pos[s] - self.origin[s])
            if pv[s] + 1 < eng.Lbuf:  # per-slot horizon guard (partial
                tiles.setdefault(U, []).append((s, int(pv[s])))  # tiles clip)
        for U, group in sorted(tiles.items()):
            gmask = np.zeros((self.B,), bool)
            gpv = np.zeros((self.B,), np.int32)
            for s, p in group:
                gmask[s] = True
                gpv[s] = p
            self.state = eng.gray_step(
                self.state, jnp.asarray(gpv), jnp.asarray(gmask), U)

    def step_chunk(self, K: int) -> list[Request]:
        """Admit queued requests into free slots, then advance every live
        slot up to K tokens with ONE fused dispatch and ONE deferred token
        readback (``FlashEngine.server_chunk``).  Streams are truncated at
        EOS/max_new on the host afterwards, so every emitted stream is
        exactly what K calls to ``step()`` would have produced; slots that
        finish mid-chunk are retired here and refilled on the next call
        (admission is chunk-granular).  Returns requests finished this call.

        Exactness caveat: the stream identity holds for greedy models
        (HyenaLCSM.advance is argmax and ignores its rng).  A model whose
        ``advance`` actually samples would see a different rng-key schedule
        here than under step() — blind overshoot steps consume splits and
        admission splits move to chunk boundaries — so chunked serving of a
        sampling model is a different (equally valid) random stream, not a
        bit-replay of the per-step one."""
        if K <= 1:
            return self.step()
        finished, pend = self.dispatch_chunk(K)
        if pend is not None:
            finished.extend(self.collect_chunk(pend))
        return finished

    def dispatch_chunk(self, K: int) -> tuple[list[Request], tuple | None]:
        """The DISPATCH half of ``step_chunk``: admit queued requests into
        free slots, launch one fused K-step ``server_chunk`` (jax async
        dispatch — returns immediately with a donated state future and a
        token future), and advance the host position bookkeeping by K,
        WITHOUT reading the tokens back.  Returns
        ``(finished_at_admission, pending)`` where ``pending`` is an opaque
        handle for :meth:`collect_chunk` — or None when no slot is live
        (nothing was dispatched).

        The split is what lets ``run()`` dispatch chunk N+1 before syncing
        on chunk N: retirement/admission then lag the device by one chunk,
        and a slot whose request retired in chunk N is stepped blindly
        through chunk N+1 — its overshoot tokens are dropped by
        ``collect_chunk`` (the record's request is already done) and its
        rows are rewritten wholesale by the refill prefill, so every
        delivered greedy stream is exactly the per-step one."""
        finished: list[Request] = []
        self._fill_free_slots(finished)
        live_slots = [s for s in range(self.B) if self.slots[s] is not None]
        if not live_slots:
            return finished, None
        # free slots idle at position 0 with live=False: the red pass still
        # computes their rows (pure per-row ops), no tiles run for them, and
        # their buffers are fully rewritten by prefill_slot on reuse.
        # Deliberately NO dynamic cap at the remaining token budget: each
        # distinct K compiles its own fused program (seconds), while the
        # blind-overshoot steps a fixed K wastes on short tails are a few
        # already-compiled red passes — truncation in collect_chunk keeps
        # streams exact either way.
        p0 = np.asarray([self.pos[s] if self.slots[s] is not None else 0
                         for s in range(self.B)], np.int32)
        origin = np.asarray(self.origin, np.int32)
        live = np.asarray([r is not None for r in self.slots], bool)
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        self.state, toks, self._rng = self.engine.server_chunk(
            self.state, p0, origin, live, self._rng, K)
        if rec is not None:
            # Async dispatch: this span is the host launch cost of chunk
            # N+1 — under run(pipeline=True) it lands BEFORE chunk N's
            # collect span on the timeline, which is the overlap the
            # dispatch-ahead refactor exists to create.
            t1 = _obs.perf_now()
            rec.add_span("server.dispatch_chunk", "server", t0, t1,
                         {"K": K, "live": len(live_slots)})
            rec.add_sample("server.live_slots", t1, len(live_slots))
            # .nbytes is shape metadata — reading it never syncs the device.
            rec.set_gauge("serving_state_bytes",
                          sum(leaf.nbytes
                              for leaf in jax.tree.leaves(self.state)))
        # Positions advance blindly by K at dispatch time (the device did
        # step every live slot K times).  A slot retiring mid-chunk leaves
        # a too-large pos behind — harmless: pos is only read for live
        # slots, and admission rewrites it.
        records = [(s, self.slots[s]) for s in live_slots]
        for s in live_slots:
            self.pos[s] += K
        return finished, (toks, records, K)

    def collect_chunk(self, pending: tuple) -> list[Request]:
        """The COLLECT half of ``step_chunk``: sync on a dispatched chunk's
        token future (``np.asarray`` — the chunk's single host sync),
        append each live record's tokens truncated at EOS/max_new, and
        retire finished slots.  Records whose request already finished in
        an earlier chunk (possible under dispatch-ahead: the slot was
        stepped blindly once more before its retirement was observed) are
        skipped — their tokens are pure overshoot."""
        toks, records, K = pending
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        toks = np.asarray(toks)
        if rec is not None:
            # The np.asarray above is the chunk's ONE host sync: this span
            # is the readback wait, i.e. the device time dispatch-ahead did
            # NOT manage to hide behind host bookkeeping.
            rec.add_span("server.collect_chunk", "server", t0,
                         _obs.perf_now(), {"K": K, "records": len(records)})
        finished: list[Request] = []
        for s, req in records:
            if req.done:
                continue  # blind overshoot chunk of an already-retired slot
            for i in range(K):
                tok = int(toks[s, i])
                req.out.append(tok)
                if tok == req.eos_id or len(req.out) >= req.max_new:
                    req.done = True
                    finished.append(req)
                    self.slots[s] = None  # tokens past this one are the
                    break                 # blind chunk's overshoot: dropped.
        return finished

    def run(self, chunk: int | None = None, *,
            pipeline: bool = True) -> list[Request]:
        """Drain queue + slots to completion.  ``chunk`` (default: the
        constructor's ``chunk``) > 1 advances slots in fused K-token chunks
        (one host sync per chunk) instead of token-by-token.

        Chunked runs DISPATCH-AHEAD by default: chunk N+1 is dispatched
        before chunk N's tokens are read back, so the host-side readback +
        bookkeeping of chunk N overlaps the device computing chunk N+1
        (``pipeline=False`` restores the strictly alternating
        dispatch-sync loop).  Greedy streams are identical either way;
        for a sampling model the pipelined admission points shift by one
        chunk, so its rng-key schedule differs — the same caveat class as
        chunked vs per-step serving (see step_chunk)."""
        K = self.chunk if chunk is None else chunk
        done: list[Request] = []
        if K is None or K <= 1:
            while self.queue or any(s is not None for s in self.slots):
                done.extend(self.step())
            return done
        if not pipeline:
            while self.queue or any(s is not None for s in self.slots):
                done.extend(self.step_chunk(K))
            return done
        pend = None
        while True:
            fin, nxt = self.dispatch_chunk(K)
            done.extend(fin)
            if pend is not None:
                done.extend(self.collect_chunk(pend))
            pend = nxt
            if nxt is None:
                # No live slots at dispatch time ⟹ nothing left in flight
                # (an uncollected chunk would have kept its slots live, so
                # the collect above already drained the last one) and an
                # empty queue (admission moved every waiter into a slot).
                return done

    # ------------------------------------------------ lockstep (batch) path
    def generate(self, prompts: np.ndarray | None, n_tokens: int,
                 seed: int = 0) -> np.ndarray:
        """prompts: (B, P) int32 or None (generate from BOS=0).
        Returns (B, n_tokens) int32 greedy samples.  All rows advance in
        lockstep — the batch-at-once regime of the paper's experiments."""
        eng, model, params = self.engine, self.model, self.params
        rng = jax.random.PRNGKey(seed)
        if prompts is not None and prompts.shape[1] > 0:
            a0 = model.embed_tokens(params, jnp.asarray(prompts))
            rng, sub = jax.random.split(rng)
            state, tok0 = eng.prefill(a0, sub)
            toks = [np.asarray(tok0)[:, None]]
            state, rest = eng.generate(
                state, n_tokens - 1, origin=prompts.shape[1], rng=rng)
            if n_tokens > 1:
                toks.append(np.asarray(rest))
            out = np.concatenate(toks, axis=1)[:, :n_tokens]
        else:
            state = eng.init_state()
            tok0 = jnp.zeros((self.batch,), jnp.int32)
            e = params["emb"][tok0]
            state = eng.set_first(state, model.embed_entry(params, e))
            state, out = eng.generate(state, n_tokens, origin=0, rng=rng)
            out = np.asarray(out)
        self.last_state = state
        return out
