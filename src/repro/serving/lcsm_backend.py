"""Serving backend for LCSM (Hyena) architectures: Flash Inference decode.

Wraps repro.core.engine.FlashEngine (Algorithms 2/3) behind the same
surface as ServingEngine.  All slots advance in lockstep positions (the
fractal tile schedule is position-indexed), so admission is batch-at-once:
a group of prompts is prefilled together (static FFT path, Massaroli
Lemma 2.1) and then generated together — the natural serving regime for
the paper's algorithm, and the one its experiments use (§5).
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.engine import FlashEngine
from repro.models.hyena import HyenaLCSM


class LCSMServer:
    def __init__(self, cfg: ModelConfig, params: Any, *, batch: int,
                 gen_max: int, prompt_max: int = 0,
                 strategy: str = "flash", tau_impl: str = "hybrid",
                 direct_max: int = 32, use_pallas: bool = False):
        assert cfg.family == "lcsm"
        self.cfg = cfg
        self.model = HyenaLCSM(cfg)
        self.params = params
        self.engine = FlashEngine(
            self.model, params, batch=batch, gen_max=gen_max,
            prompt_max=prompt_max, strategy=strategy, tau_impl=tau_impl,
            direct_max=direct_max, use_pallas=use_pallas)
        self.batch = batch

    def generate(self, prompts: np.ndarray | None, n_tokens: int,
                 seed: int = 0) -> np.ndarray:
        """prompts: (B, P) int32 or None (generate from BOS=0).
        Returns (B, n_tokens) int32 greedy samples."""
        eng, model, params = self.engine, self.model, self.params
        state = eng.init_state()
        if prompts is not None and prompts.shape[1] > 0:
            a0 = model.embed_tokens(params, jnp.asarray(prompts))
            state = eng.prefill(state, a0)
            origin = prompts.shape[1]
        else:
            tok0 = jnp.zeros((self.batch,), jnp.int32)
            e = params["emb"][tok0]
            state = eng.set_first(state, model.embed_entry(params, e))
            origin = 0
        state, toks = eng.generate(
            state, n_tokens, origin=origin, rng=jax.random.PRNGKey(seed))
        self.last_state = state
        return np.asarray(toks)
