"""Serving backend for generic ("and Beyond") mixer families: continuously
batched decode through the §4 GenericFlashEngine.

``GenericServer`` IS the slot bookkeeping of ``LCSMServer`` — admission by
single-slot prefill, per-slot tile schedules, per-(slot, tile-side) gray
dispatch, fused ``step_chunk(K)`` with deferred readback, EOS/max_new
retirement — pointed at a different engine/model pair: the generic
schedule walker over ``GatedLinearAttention`` language models
(``cfg.family == "gla"``).  That the subclass overrides ONLY construction
is the point of the PR that introduced it: everything the LCSM server
does is a property of the shared fractal-schedule machinery
(core/schedule.ScheduleWalker), not of long convolutions.

Exactness bar (tests/test_serving_continuous.py): every stream emitted
under slot sharing equals ``isolated_decode`` of the same prompt — the
batch-1 lockstep reference below — per-step and chunked.
"""

from __future__ import annotations

from typing import Any

from repro.configs.base import ModelConfig
from repro.core.generic import GenericFlashEngine
from repro.models.gla import GLALM
from repro.serving.lcsm_backend import LCSMServer, isolated_decode_via


def isolated_decode(cfg: ModelConfig, params: Any, prompt, n_tokens: int, *,
                    prompt_max: int, gen_max: int) -> list[int]:
    """Isolated batch-1 lockstep greedy decode through the generic engine —
    the exactness reference for GLA continuous batching (tests and
    examples/serve_batched.py).  ``prompt_max``/``gen_max`` should match
    the server under comparison (they size Lbuf; GLA values are
    Lbuf-independent, but keeping them equal makes the comparison a pure
    slot-sharing differential).  Delegates to the single shared reference
    implementation (lcsm_backend.isolated_decode_via)."""
    model = GLALM(cfg)
    eng = GenericFlashEngine(model, params, batch=1, gen_max=gen_max,
                             prompt_max=prompt_max)
    return isolated_decode_via(model, eng, params, prompt, n_tokens)


class GenericServer(LCSMServer):
    """Continuous-batching server for ``cfg.family == "gla"`` archs.

    Same ``submit()/step()/step_chunk()/run()/generate()`` surface and
    bookkeeping as LCSMServer (inherited verbatim); only the engine/model
    construction differs.  The generic engine is flash-only (no Ω(L²)
    lazy/eager baselines) and currently single-device (``mesh`` must be
    None — the LCSM backend shows the pattern if sharding is wanted)."""

    def __init__(self, cfg: ModelConfig, params: Any, *,
                 n_slots: int | None = None, batch: int | None = None,
                 gen_max: int, prompt_max: int = 0, strategy: str = "flash",
                 chunk: int | None = None, chunk_size: int = 1,
                 mesh=None, seed: int = 0):
        assert cfg.family == "gla"
        assert strategy == "flash", "generic engine has no lazy/eager baselines"
        assert mesh is None, "GenericServer is single-device for now"
        if n_slots is None:
            n_slots = 1 if batch is None else batch
        self.cfg = cfg
        self.model = GLALM(cfg)
        self.params = params
        self.mesh = None
        self.engine = GenericFlashEngine(
            self.model, params, batch=n_slots, gen_max=gen_max,
            prompt_max=prompt_max, chunk_size=chunk_size)
        self._init_slot_bookkeeping(
            n_slots, strategy=strategy, gen_max=gen_max,
            prompt_max=prompt_max, chunk=chunk, chunk_size=chunk_size,
            seed=seed)
