from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.lcsm_backend import LCSMServer  # noqa: F401
