"""Serving backends, unified behind one factory.

Three slot-based continuous-batching servers share the
``submit()/step()/run()`` surface:

* ``ServingEngine``  — transformer-family archs (KV / MLA / SSM caches).
* ``LCSMServer``     — LCSM (Hyena) archs via the Flash Inference engine,
  with a per-slot tile schedule (see serving/lcsm_backend.py).
* ``GenericServer``  — "and Beyond" generic-mixer archs (GLA) via the §4
  GenericFlashEngine on the same schedule machinery
  (see serving/generic_backend.py).

``make_server`` picks by ``cfg.family``.
"""

from __future__ import annotations

from typing import Any

from repro.configs.base import ModelConfig
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.generic_backend import GenericServer  # noqa: F401
from repro.serving.lcsm_backend import LCSMServer  # noqa: F401


def make_server(cfg: ModelConfig, params: Any, *, n_slots: int,
                max_seq: int = 64, prompt_max: int = 16,
                gen_max: int = 32, frontend: dict | None = None,
                replicas: int | None = None, **kw):
    """Build the serving backend for ``cfg``.

    ``max_seq`` sizes transformer caches; ``prompt_max``/``gen_max`` size
    the LCSM/GLA per-slot buffers (Lbuf = prompt_max + ceil_pow2(gen_max)).
    Extra keyword args go to the chosen backend (e.g. ``strategy=`` /
    ``tau_impl=`` / ``chunk=`` / ``seed=`` for LCSM, ``chunk=`` / ``seed=``
    for GLA, ``window=`` / ``cache_dtype=`` for the rest).
    ``mesh=`` (transformer + LCSM backends) shards serving slots over the
    mesh's 'data' axis and channels/decode state over 'model' — see
    launch/mesh.make_serving_mesh and README "Multi-device serving".

    ``replicas=N`` (> 1) returns a
    :class:`~repro.serving.frontend.replicas.ReplicaSet` instead: N
    independent single-device servers (one per visible device, ``n_slots``
    slots EACH) with frontend-level request routing — data parallelism
    with no collectives.  Mutually exclusive with ``mesh=``.

    ``frontend=`` (a kwargs dict for
    ``repro.serving.frontend.make_frontend``: ``policy=``,
    ``queue_limit=``, ``prefix_cache=``/``prefix_cache_bytes=``,
    ``chunk=``) wraps the backend in a traffic-serving
    :class:`~repro.serving.frontend.TrafficScheduler` (or the replica-
    routing scheduler for a ReplicaSet) — timed arrivals, streaming token
    delivery, prefix-state caching (LCSM/GLA only), and latency telemetry
    — and returns the scheduler (the raw server stays reachable as
    ``scheduler.server``).  See README "Serving frontend".
    """
    if replicas is not None and replicas > 1:
        from repro.serving.frontend.replicas import ReplicaSet
        srv = ReplicaSet(cfg, params, replicas=replicas, n_slots=n_slots,
                         max_seq=max_seq, prompt_max=prompt_max,
                         gen_max=gen_max, **kw)
    elif cfg.family == "lcsm":
        srv = LCSMServer(cfg, params, n_slots=n_slots,
                         prompt_max=prompt_max, gen_max=gen_max, **kw)
    elif cfg.family == "gla":
        srv = GenericServer(cfg, params, n_slots=n_slots,
                            prompt_max=prompt_max, gen_max=gen_max, **kw)
    else:
        srv = ServingEngine(cfg, params, n_slots=n_slots, max_seq=max_seq,
                            **kw)
    if frontend is not None:
        from repro.serving.frontend import make_frontend
        return make_frontend(srv, **frontend)
    return srv
