"""Serving backends, unified behind one factory.

Three slot-based continuous-batching servers share the
``submit()/step()/run()`` surface:

* ``ServingEngine``  — transformer-family archs (KV / MLA / SSM caches).
* ``LCSMServer``     — LCSM (Hyena) archs via the Flash Inference engine,
  with a per-slot tile schedule (see serving/lcsm_backend.py).
* ``GenericServer``  — "and Beyond" generic-mixer archs (GLA) via the §4
  GenericFlashEngine on the same schedule machinery
  (see serving/generic_backend.py).

``make_server`` picks by ``cfg.family``.
"""

from __future__ import annotations

from typing import Any

from repro.configs.base import ModelConfig
from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.generic_backend import GenericServer  # noqa: F401
from repro.serving.lcsm_backend import LCSMServer  # noqa: F401


def make_server(cfg: ModelConfig, params: Any, *, n_slots: int,
                max_seq: int = 64, prompt_max: int = 16,
                gen_max: int = 32, **kw):
    """Build the serving backend for ``cfg``.

    ``max_seq`` sizes transformer caches; ``prompt_max``/``gen_max`` size
    the LCSM/GLA per-slot buffers (Lbuf = prompt_max + ceil_pow2(gen_max)).
    Extra keyword args go to the chosen backend (e.g. ``strategy=`` /
    ``tau_impl=`` for LCSM, ``window=`` / ``cache_dtype=`` for the rest).
    ``mesh=`` (transformer + LCSM backends) shards serving slots over the
    mesh's 'data' axis and channels/decode state over 'model' — see
    launch/mesh.make_serving_mesh and README "Multi-device serving".
    """
    if cfg.family == "lcsm":
        return LCSMServer(cfg, params, n_slots=n_slots,
                          prompt_max=prompt_max, gen_max=gen_max, **kw)
    if cfg.family == "gla":
        return GenericServer(cfg, params, n_slots=n_slots,
                             prompt_max=prompt_max, gen_max=gen_max, **kw)
    return ServingEngine(cfg, params, n_slots=n_slots, max_seq=max_seq, **kw)
