"""AdamW + cosine schedule + global-norm clipping, pure JAX pytrees.

State mirrors the param pytree (m, v) plus a step counter; everything is a
plain function usable under jit/pjit (optimizer states shard like params).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_F32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def adamw_init(params) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=_F32), params)
    return OptState(m=z, v=jax.tree.map(jnp.copy, z), step=jnp.zeros((), jnp.int32))


def cosine_lr(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = step.astype(_F32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm_clip(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(_F32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(_F32) * scale).astype(g.dtype), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, st: OptState):
    """Returns (new_params, new_state, metrics dict)."""
    grads, gnorm = global_norm_clip(grads, cfg.clip_norm)
    step = st.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(_F32)
    bc2 = 1 - b2 ** step.astype(_F32)

    def upd(p, g, m, v):
        g = g.astype(_F32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat, vhat = m / bc1, v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(_F32)
        return (p.astype(_F32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(st.m)
    flat_v = treedef.flatten_up_to(st.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {"lr": lr, "grad_norm": gnorm}
