"""Shared benchmark utilities: timed jit calls (warm-up per the paper §5:
2 warm-up runs, then average over 4), CSV emission."""

from __future__ import annotations

import csv
import os
import time
from typing import Callable

import jax

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 4) -> float:
    """Median-free paper protocol: warm-up then mean wall-time (seconds)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return os.path.abspath(path)
