"""Shared benchmark utilities: timed jit calls (warm-up per the paper §5:
2 warm-up runs, then average over 4), CSV emission, and the one JSON schema
every BENCH_*.json record follows:

    {"bench": <name>, "machine": {...}, "config": {...}, "series": [...]}

``machine`` captures the backend/devices the numbers were measured on,
``config`` the swept workload, ``series`` one dict per measured cell.
tests/test_bench_schema.py loads every committed BENCH_*.json against it.
"""

from __future__ import annotations

import csv
import json
import os
import platform
import time
from typing import Callable

import jax

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def machine_info() -> dict:
    """Where the numbers came from (goes into every BENCH json)."""
    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": devs[0].device_kind if devs else "none",
        "python": platform.python_version(),
        "jax": jax.__version__,
    }


def git_sha() -> str:
    """Short HEAD sha, or "unknown" outside a repo / without git."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(__file__), capture_output=True, text=True,
            timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def append_history(bench: str, config: dict, series: list[dict], *,
                   smoke: bool = False) -> str:
    """Append this sweep's summary line to the benchmark trajectory.

    Full runs append to the committed ``HISTORY.jsonl`` (one line per
    sweep: git sha, timestamp, machine, headline tok/s) so the repo
    finally RECORDS its own performance trajectory; smoke runs go to the
    gitignored ``history_smoke.jsonl`` (CI noise stays out of the
    committed record).  ``benchmarks/compare.py`` diffs fresh numbers
    against the committed baseline cell-by-cell."""
    os.makedirs(OUT_DIR, exist_ok=True)
    rates = [c["tok_s"] for c in series
             if isinstance(c.get("tok_s"), (int, float))]
    entry = {
        "bench": bench,
        "git": git_sha(),
        "time": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "smoke": smoke,
        "machine": machine_info(),
        "config": config,
        "headline": {
            "cells": len(series),
            "tok_s_max": round(max(rates), 2) if rates else None,
            "tok_s_mean": round(sum(rates) / len(rates), 2) if rates
            else None,
        },
    }
    path = os.path.join(
        OUT_DIR, "history_smoke.jsonl" if smoke else "HISTORY.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(entry) + "\n")
    return os.path.abspath(path)


def write_bench_json(bench: str, config: dict, series: list[dict], *,
                     smoke: bool = False) -> str:
    """Write the normalized record.  Full runs go to the committed
    ``BENCH_<bench>.json``; smoke runs to ``<bench>_smoke.json`` (gitignored)
    so CI never clobbers the committed numbers.  Every write also appends
    a summary line to the bench-history trajectory (see append_history)."""
    os.makedirs(OUT_DIR, exist_ok=True)
    stem = f"{bench}_smoke" if smoke else f"BENCH_{bench}"
    path = os.path.join(OUT_DIR, f"{stem}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench, "machine": machine_info(),
                   "config": config, "series": series}, f, indent=1)
    append_history(bench, config, series, smoke=smoke)
    return os.path.abspath(path)


def serving_requests(cfg, n_reqs: int, prompt_max: int, gen_max: int,
                     seed: int = 0) -> list:
    """The shared mixed-length request trace the serving-style benchmarks
    sweep (bench_serving, bench_sharded): random prompts in [1, prompt_max],
    outputs in [gen_max/2, gen_max], deterministic per seed."""
    import numpy as np

    from repro.serving import Request

    rng = np.random.RandomState(seed)
    return [
        Request(uid=i,
                prompt=rng.randint(0, cfg.vocab,
                                   (int(rng.randint(1, prompt_max + 1)),)
                                   ).astype(np.int32),
                max_new=int(rng.randint(gen_max // 2, gen_max + 1)))
        for i in range(n_reqs)
    ]


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 4) -> float:
    """Median-free paper protocol: warm-up then mean wall-time (seconds)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return os.path.abspath(path)
