"""Device-resident chunked decode throughput: tok/s vs chunk size K.

The per-step decode loop pays a host round-trip (dispatch + token readback)
per token; ``decode_chunk`` fuses K schedule steps into one donated XLA
computation, so dispatch overhead amortizes K-fold while the arithmetic is
bit-identical (tests/test_decode_chunk.py).  This benchmark measures the
batch-1 regime the paper's small-batch latency story (FutureFill, Laughing
Hyena Distillery comparisons) cares about, for all three mixer strategies:

    PYTHONPATH=src python -m benchmarks.bench_decode [--smoke]

Emits experiments/bench/BENCH_decode.json (normalized
{bench, machine, config, series} schema; one series entry per
(strategy, K)) plus the usual CSV.  K=1 is the historical per-step path —
the speedup column is tok_s(K) / tok_s(K=1) within each strategy.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.core.engine import FlashEngine
from repro.models.synthetic_lcsm import SyntheticLCSM

from benchmarks.common import write_bench_json, write_csv


def run_cell(model, params, *, strategy: str, K: int, L: int, batch: int = 1):
    eng = FlashEngine(model, params, batch=batch, gen_max=L,
                      strategy=strategy, chunk_size=K)

    def fresh():
        state = eng.init_state()
        return eng.set_first(
            state, jax.random.normal(jax.random.PRNGKey(1), (batch, model.d)))

    def decode():
        state, toks = eng.generate(fresh(), L, rng=jax.random.PRNGKey(2))
        jax.block_until_ready(state.a[0])

    decode()  # warm-up: compiles every chunk segment / per-step program
    t0 = time.perf_counter()
    decode()
    dt = time.perf_counter() - t0
    return {"strategy": strategy, "chunk_K": K, "batch": batch, "tokens": L,
            "seconds": round(dt, 4), "tok_s": round(L * batch / dt, 2)}


def main(smoke: bool = False) -> str:
    M, D = (2, 32) if smoke else (3, 64)
    L = 64 if smoke else 256
    Ks = (1, 4, 8) if smoke else (1, 2, 4, 8, 16, 32)
    strategies = ("flash", "lazy") if smoke else ("flash", "lazy", "eager")
    model = SyntheticLCSM(n_levels=M, d_model=D)
    params = model.init(jax.random.PRNGKey(0))

    records = []
    for strategy in strategies:
        base = None
        for K in Ks:
            rec = run_cell(model, params, strategy=strategy, K=K, L=L)
            base = rec["tok_s"] if K == 1 else base
            rec["speedup_vs_per_step"] = round(rec["tok_s"] / base, 2)
            records.append(rec)
            print(f"[bench_decode] {strategy:6s} K={K:3d}: "
                  f"{rec['tokens']} tok in {rec['seconds']:.3f}s  "
                  f"{rec['tok_s']:9.1f} tok/s  "
                  f"(x{rec['speedup_vs_per_step']:.2f} vs per-step)")

    path = write_bench_json(
        "decode",
        {"model": f"synthetic M={M} D={D}", "tokens": L, "batch": 1,
         "chunk_sizes": list(Ks), "strategies": list(strategies)},
        records, smoke=smoke)
    write_csv("decode_chunk_smoke" if smoke else "decode_chunk",
              ["strategy", "chunk_K", "tokens", "seconds", "tok_s",
               "speedup_vs_per_step"],
              [[r["strategy"], r["chunk_K"], r["tokens"], r["seconds"],
                r["tok_s"], r["speedup_vs_per_step"]] for r in records])
    print(f"[bench_decode] wrote {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized run")
    args = ap.parse_args()
    main(smoke=args.smoke)
