"""Cumulative mixer-time scaling: flash vs lazy vs eager (paper Fig. 2b).

Runs the synthetic LCSM (§5 setup, reduced to CPU scale) with the three
strategies and reports cumulative wall time and the flash/naive ratio —
the paper's '50× on the mixer part' claim, at whatever scale L allows here.
The mixer-only cost is isolated by timing generate() with blocks reduced
to identity-free MLPs shared across strategies (identical non-mixer work).
"""

from __future__ import annotations

import time

import jax

from repro.core.engine import FlashEngine
from repro.models.synthetic_lcsm import SyntheticLCSM

from benchmarks.common import write_csv


def run_strategy(strategy: str, L: int, M: int = 4, D: int = 128, B: int = 1):
    model = SyntheticLCSM(n_levels=M, d_model=D)
    params = model.init(jax.random.PRNGKey(0))
    eng = FlashEngine(model, params, batch=B, gen_max=L, strategy=strategy)
    state = eng.init_state()
    state = eng.set_first(state, jax.random.normal(jax.random.PRNGKey(1), (B, D)))
    # warm-up: run the FULL schedule once so every per-tile-size program
    # is compiled before timing (the paper's protocol: 2 warm-up runs).
    s2, _ = eng.generate(state, L, rng=jax.random.PRNGKey(2))
    jax.block_until_ready(s2.a[0])
    state = eng.init_state()
    state = eng.set_first(state, jax.random.normal(jax.random.PRNGKey(1), (B, D)))
    t0 = time.perf_counter()
    state, _ = eng.generate(state, L, rng=jax.random.PRNGKey(2))
    jax.block_until_ready(state.a[0])
    return time.perf_counter() - t0


def main(Ls=(256, 1024, 4096)) -> str:
    rows = []
    for L in Ls:
        tf = run_strategy("flash", L)
        tl = run_strategy("lazy", L)
        te = run_strategy("eager", L)
        rows.append([L, f"{tf:.3f}", f"{tl:.3f}", f"{te:.3f}",
                     f"{min(tl, te) / tf:.2f}"])
        print(f"[bench_mixer] L={L:5d}  flash {tf:7.3f}s  lazy {tl:7.3f}s  "
              f"eager {te:7.3f}s  speedup x{min(tl, te) / tf:.2f}")
    path = write_csv("mixer_scaling",
                     ["L", "flash_s", "lazy_s", "eager_s", "speedup"], rows)
    print(f"[bench_mixer] wrote {path}")
    return path


if __name__ == "__main__":
    main()
