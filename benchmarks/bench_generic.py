"""Generic-engine ("and Beyond") decode throughput: GLA flash vs the
recurrent RNN-mode oracle, across decode length L and chunk size K.

    PYTHONPATH=src python -m benchmarks.bench_generic [--smoke]

What the numbers mean: GLA is the honesty check for the generic framework
— unlike long convolutions it ADMITS a compact O(1)-state recurrence, so
the scan-based RNN mode is the hardware speed-of-light for this mixer and
the flash schedule's generality has a measurable price (tile dispatches +
O(log L) state rows touched instead of one).  The interesting curves are
(a) how much of that price the fused chunk path (K) buys back — the same
dispatch-amortization story bench_decode.py tells for Hyena — and (b) how
the gap scales with L.  For mixers with no compact recurrence (the
paper's main subjects) the recurrent column does not exist and flash is
the only sub-quadratic autoregressive option.

Emits experiments/bench/BENCH_generic.json in the pinned
{bench, machine, config, series} schema (tests/test_bench_schema.py) plus
the usual CSV.  Streams are verified identical across modes before
timing — a benchmark over diverging decodes would be meaningless.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.generic import GenericFlashEngine
from repro.models.gla import GLALM

from benchmarks.common import write_bench_json, write_csv


def _recurrent_decode_fn(model: GLALM, params, L: int, batch: int):
    """One jitted lax.scan over L greedy RNN-mode steps (device-resident:
    the strongest recurrent baseline, one dispatch for the whole decode)."""
    def step(carry, _):
        u, S = carry
        mixers = model.mixers(params)
        S2 = []
        h = u
        for l, mix in enumerate(mixers):
            s_l = mix.step_state(S[l], h)
            S2.append(s_l)
            z = mix.read(s_l, h)
            h = model.block(params, l, z[:, None], h[:, None])[:, 0]
        logits = model.logits(params, h)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (params["emb"][tok], tuple(S2)), tok

    @jax.jit
    def decode(u0):
        S0 = tuple(jnp.zeros((batch, m.dk, m.dv), jnp.float32)
                   for m in model.mixers(params))
        (_, _), toks = jax.lax.scan(step, (u0, S0), None, length=L)
        return toks.T  # (B, L)

    return decode


def run_flash(model, params, *, L: int, K: int, batch: int = 1):
    eng = GenericFlashEngine(model, params, batch=batch, gen_max=L,
                             chunk_size=K)
    u0 = model.embed_tokens(params, jnp.zeros((batch, 1), jnp.int32))[:, 0]

    def decode():
        state = eng.set_first(eng.init_state(), u0)
        state, toks = eng.generate(state, L, rng=jax.random.PRNGKey(2))
        jax.block_until_ready(state.a[0])
        return np.asarray(toks)

    toks = decode()  # warm-up: compiles every chunk segment
    t0 = time.perf_counter()
    decode()
    dt = time.perf_counter() - t0
    return toks, {"mode": "flash", "chunk_K": K, "L": L, "batch": batch,
                  "tokens": L, "seconds": round(dt, 4),
                  "tok_s": round(L * batch / dt, 2)}


def run_recurrent(model, params, *, L: int, batch: int = 1):
    decode = _recurrent_decode_fn(model, params, L, batch)
    u0 = model.embed_tokens(params, jnp.zeros((batch, 1), jnp.int32))[:, 0]
    toks = np.asarray(jax.block_until_ready(decode(u0)))  # warm-up/compile
    t0 = time.perf_counter()
    jax.block_until_ready(decode(u0))
    dt = time.perf_counter() - t0
    return toks, {"mode": "recurrent", "chunk_K": 0, "L": L, "batch": batch,
                  "tokens": L, "seconds": round(dt, 4),
                  "tok_s": round(L * batch / dt, 2)}


def main(smoke: bool = False) -> str:
    import dataclasses

    from repro.configs import get_config

    if smoke:
        cfg = dataclasses.replace(get_config("gla").smoke(), name="gla-bench",
                                  n_layers=2, d_model=32, d_ff=64, vocab=256,
                                  gla_dk=8, gla_dv=32)
        Ls, Ks = (32,), (1, 4)
    else:
        cfg = dataclasses.replace(get_config("gla").smoke(), name="gla-bench",
                                  n_layers=2, d_model=64, d_ff=128, vocab=512,
                                  gla_dk=16, gla_dv=64)
        Ls, Ks = (64, 256), (1, 4, 16)
    model = GLALM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    records = []
    for L in Ls:
        ref_toks, rec = run_recurrent(model, params, L=L)
        records.append(rec)
        print(f"[bench_generic] recurrent    L={L:4d}: "
              f"{rec['seconds']:.3f}s  {rec['tok_s']:9.1f} tok/s")
        base = None
        for K in Ks:
            toks, cell = run_flash(model, params, L=L, K=K)
            # greedy streams must agree before the timing means anything
            assert np.array_equal(toks, ref_toks), \
                f"flash(K={K}) diverged from recurrent oracle at L={L}"
            base = cell["tok_s"] if K == 1 else base
            cell["speedup_vs_per_step"] = round(cell["tok_s"] / base, 2)
            records.append(cell)
            print(f"[bench_generic] flash K={K:3d} L={L:4d}: "
                  f"{cell['seconds']:.3f}s  {cell['tok_s']:9.1f} tok/s  "
                  f"(x{cell['speedup_vs_per_step']:.2f} vs per-step)")

    path = write_bench_json(
        "generic",
        {"model": f"gla M={cfg.n_layers} D={cfg.d_model} "
                  f"dk={cfg.gla_dk} dv={cfg.gla_dv}",
         "lengths": list(Ls), "chunk_sizes": list(Ks), "batch": 1,
         "modes": ["flash", "recurrent"],
         "streams_identical_across_modes": True},
        records, smoke=smoke)
    write_csv("generic_smoke" if smoke else "generic",
              ["mode", "chunk_K", "L", "tokens", "seconds", "tok_s"],
              [[r["mode"], r["chunk_K"], r["L"], r["tokens"], r["seconds"],
                r["tok_s"]] for r in records])
    print(f"[bench_generic] wrote {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized run")
    args = ap.parse_args()
    main(smoke=args.smoke)
