"""Diff a fresh BENCH json against the committed baseline, flagging
throughput regressions — the other half of the bench trajectory
(``HISTORY.jsonl`` records it, this compares against it).

    PYTHONPATH=src python -m benchmarks.compare --bench traffic
    PYTHONPATH=src python -m benchmarks.compare fresh.json baseline.json

With ``--bench <name>`` the fresh side defaults to the smoke artifact
``experiments/bench/<name>_smoke.json`` (what CI just produced) and the
baseline to the committed ``BENCH_<name>.json``.  Cells are matched by
their identity keys (everything that is not a measured metric); matched
cells whose ``tok_s`` dropped by more than ``--threshold`` are flagged.
Exit code 1 on any regression unless ``--warn-only`` (the CI smoke job
runs warn-only: hosted-runner CPU numbers are noisy, and a smoke config
differs from the committed full sweep — unmatched cells are reported,
never flagged)."""

from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import OUT_DIR

# Measured outputs — everything else in a series cell identifies it.
METRIC_KEYS = {
    "seconds", "tok_s", "tokens", "speedup_vs_per_step", "speedup_vs_lazy",
    "ttft_mean_s", "ttft_p95_s", "token_gap_mean_s", "queue_depth_mean",
    "slot_occupancy_mean", "cache_hits", "completed", "dispatches",
    "dispatches_per_token", "wall_s",
}


def cell_identity(cell: dict) -> tuple:
    return tuple(sorted((k, repr(v)) for k, v in cell.items()
                        if k not in METRIC_KEYS))


def compare(fresh: dict, baseline: dict, threshold: float) -> dict:
    """Match cells by identity and diff ``tok_s``.  Returns
    {"matched": [...], "regressions": [...], "unmatched_fresh": n,
    "unmatched_base": n}."""
    base_by_id = {cell_identity(c): c for c in baseline["series"]}
    matched, regressions = [], []
    unmatched = 0
    for cell in fresh["series"]:
        ident = cell_identity(cell)
        base = base_by_id.pop(ident, None)
        if base is None or not isinstance(cell.get("tok_s"), (int, float)) \
                or not isinstance(base.get("tok_s"), (int, float)) \
                or base["tok_s"] <= 0:
            unmatched += 1
            continue
        delta = (cell["tok_s"] - base["tok_s"]) / base["tok_s"]
        row = {"cell": dict(ident), "base_tok_s": base["tok_s"],
               "new_tok_s": cell["tok_s"], "delta_pct": round(delta * 100, 1),
               "regressed": delta < -threshold}
        matched.append(row)
        if row["regressed"]:
            regressions.append(row)
    return {"matched": matched, "regressions": regressions,
            "unmatched_fresh": unmatched, "unmatched_base": len(base_by_id)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help="fresh.json [baseline.json] (explicit file mode)")
    ap.add_argument("--bench", action="append", default=[],
                    help="bench name(s): compare "
                         "<name>_smoke.json vs BENCH_<name>.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative tok_s drop that counts as a regression "
                         "(default 0.2 = 20%%)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (CI smoke mode)")
    args = ap.parse_args(argv)

    pairs: list[tuple[str, str]] = []
    if args.paths:
        if len(args.paths) != 2:
            ap.error("file mode takes exactly: fresh.json baseline.json")
        pairs.append((args.paths[0], args.paths[1]))
    for name in args.bench:
        pairs.append((os.path.join(OUT_DIR, f"{name}_smoke.json"),
                      os.path.join(OUT_DIR, f"BENCH_{name}.json")))
    if not pairs:
        ap.error("give two json paths or at least one --bench NAME")

    any_regression = False
    for fresh_path, base_path in pairs:
        if not os.path.exists(fresh_path):
            print(f"compare: SKIP (no fresh file) {fresh_path}")
            continue
        if not os.path.exists(base_path):
            print(f"compare: SKIP (no baseline) {base_path}")
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        with open(base_path) as f:
            baseline = json.load(f)
        res = compare(fresh, baseline, args.threshold)
        tag = fresh.get("bench", os.path.basename(fresh_path))
        if fresh.get("config") != baseline.get("config"):
            print(f"compare[{tag}]: NOTE sweep configs differ "
                  "(e.g. smoke vs full) — deltas are apples-to-oranges; "
                  "matched cells share identity keys only")
        print(f"compare[{tag}]: {len(res['matched'])} matched cells, "
              f"{res['unmatched_fresh']} fresh-only, "
              f"{res['unmatched_base']} baseline-only")
        for row in res["matched"]:
            mark = "REGRESSION" if row["regressed"] else "ok"
            print(f"  {mark:>10}  {row['base_tok_s']:10.1f} -> "
                  f"{row['new_tok_s']:10.1f} tok/s ({row['delta_pct']:+.1f}%) "
                  f" {dict(row['cell'])}")
        if res["regressions"]:
            any_regression = True
            print(f"compare[{tag}]: {len(res['regressions'])} cell(s) "
                  f"slower than baseline by > {args.threshold:.0%}")

    if any_regression and not args.warn_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
