"""Roofline table (deliverable g): aggregates experiments/dryrun/*.json into
the per-(arch × shape × mesh) report of DESIGN §7 — three terms in seconds,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import write_csv

DRY_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRY_DIR, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        if mesh is None or r["mesh"] == mesh:
            recs.append(_fix_analytic(r))
    return recs


def _fix_analytic(r: dict) -> dict:
    """Correct records written before the while-trip-count fix: XLA's
    cost_analysis counts loop bodies once, so scanned programs under-report
    FLOPs; the compute term takes max(HLO, analytic/chips)."""
    if r.get("analytic_flops"):
        return r
    try:
        from repro.configs import get_config
        from repro.launch.analysis import analytic_flops
        from repro.launch.mesh import PEAK_FLOPS_BF16

        shape = r["shape"].split("-gray")[0]
        ana = analytic_flops(get_config(r["arch"]), shape)
        if "-gray" in r["shape"]:
            ana = 0.0
        r["analytic_flops"] = ana
        flops_eff = max(r["hlo_flops"], ana / r["chips"])
        r["compute_s"] = flops_eff / PEAK_FLOPS_BF16
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        r["bottleneck"] = max(terms, key=terms.get)
        tot = max(r["hlo_flops"] * r["chips"], ana)
        r["useful_ratio"] = r["model_flops"] / tot if tot else float("nan")
    except Exception:
        r.setdefault("analytic_flops", 0.0)
    return r


def fmt_table(recs: list[dict]) -> str:
    hdr = (f"{'arch':28s} {'shape':22s} {'mesh':10s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'bound':>10s} {'useful':>7s} "
           f"{'GiB/chip':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        gib = r.get("memory_analysis", {}).get("argument_size_in_bytes", 0) / 2**30
        lines.append(
            f"{r['arch']:28s} {r['shape']:22s} {r['mesh']:10s} "
            f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
            f"{r['collective_s']:10.3e} {r['bottleneck']:>10s} "
            f"{r['useful_ratio']:7.3f} {gib:8.2f}")
    return "\n".join(lines)


def main() -> str:
    recs = load_records()
    if not recs:
        print("[roofline] no dry-run records found — run repro.launch.dryrun first")
        return ""
    print(fmt_table(recs))
    rows = [[r["arch"], r["shape"], r["mesh"], r["chips"],
             f"{r['hlo_flops']:.4e}", f"{r['hlo_bytes']:.4e}",
             f"{r['coll_bytes']:.4e}", f"{r['compute_s']:.4e}",
             f"{r['memory_s']:.4e}", f"{r['collective_s']:.4e}",
             r["bottleneck"], f"{r['model_flops']:.4e}",
             f"{r['useful_ratio']:.4f}", r.get("note", "")]
            for r in recs]
    path = write_csv("roofline",
                     ["arch", "shape", "mesh", "chips", "hlo_flops_per_chip",
                      "hlo_bytes_per_chip", "coll_bytes_per_chip", "compute_s",
                      "memory_s", "collective_s", "bottleneck", "model_flops",
                      "useful_ratio", "note"], rows)
    print(f"[roofline] wrote {path}")
    return path


if __name__ == "__main__":
    main()
