"""Continuous-batching serving throughput for the LCSM (Hyena) backend:
tok/s vs slot count, flash vs lazy mixer strategies, over a mixed
prompt/output-length request stream.

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]

Emits experiments/bench/BENCH_serving.json (one record per
(strategy, n_slots) cell) plus the usual CSV.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.serving import Request, make_server

from benchmarks.common import OUT_DIR, write_csv


def _requests(cfg, n_reqs, prompt_max, gen_max, seed=0):
    rng = np.random.RandomState(seed)
    return [
        Request(uid=i,
                prompt=rng.randint(0, cfg.vocab,
                                   (int(rng.randint(1, prompt_max + 1)),)
                                   ).astype(np.int32),
                max_new=int(rng.randint(gen_max // 2, gen_max + 1)))
        for i in range(n_reqs)
    ]


def run_cell(cfg, params, *, strategy, n_slots, n_reqs, prompt_max, gen_max):
    srv = make_server(cfg, params, n_slots=n_slots, prompt_max=prompt_max,
                      gen_max=gen_max, strategy=strategy)
    for r in _requests(cfg, n_reqs, prompt_max, gen_max):
        srv.submit(r)
    # warm-up pass compiles the red step + per-(tile-side, prompt-length)
    # specializations; a second identical stream is then timed.
    srv.run()
    for r in _requests(cfg, n_reqs, prompt_max, gen_max):
        srv.submit(r)
    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    return {"arch": cfg.name, "family": cfg.family, "strategy": strategy,
            "n_slots": n_slots, "n_requests": n_reqs, "tokens": toks,
            "seconds": round(dt, 4), "tok_s": round(toks / dt, 2),
            "prompt_max": prompt_max, "gen_max": gen_max}


def main(smoke: bool = False, n_ops: int = 2, d_model: int = 64,
         slot_counts=(1, 2, 4)) -> str:
    cfg = dataclasses.replace(
        get_config("hyena").smoke(), name="hyena-serve-bench",
        n_layers=2 * n_ops, d_model=d_model, d_ff=2 * d_model, vocab=512)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    prompt_max, gen_max = (4, 8) if smoke else (8, 32)
    n_reqs = 6 if smoke else 16
    if smoke:
        slot_counts = tuple(slot_counts)[:2]

    records = []
    for strategy in ("flash", "lazy"):
        for n_slots in slot_counts:
            rec = run_cell(cfg, params, strategy=strategy, n_slots=n_slots,
                           n_reqs=n_reqs, prompt_max=prompt_max,
                           gen_max=gen_max)
            records.append(rec)
            print(f"[bench_serving] {strategy:6s} slots={n_slots}: "
                  f"{rec['tokens']} tok in {rec['seconds']:.2f}s  "
                  f"{rec['tok_s']:8.1f} tok/s")

    os.makedirs(OUT_DIR, exist_ok=True)
    # Smoke runs must not clobber the committed full-run BENCH record.
    stem = "serving_smoke" if smoke else "BENCH_serving"
    path = os.path.join(OUT_DIR, f"{stem}.json")
    with open(path, "w") as f:
        json.dump({"bench": "serving", "records": records}, f, indent=1)
    write_csv("serving_smoke" if smoke else "serving",
              ["strategy", "n_slots", "tokens", "seconds", "tok_per_s"],
              [[r["strategy"], r["n_slots"], r["tokens"], r["seconds"],
                r["tok_s"]] for r in records])
    print(f"[bench_serving] wrote {os.path.abspath(path)}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream (CI-sized)")
    args = ap.parse_args()
    main(smoke=args.smoke)
