"""Continuous-batching serving throughput for the LCSM (Hyena) backend:
tok/s vs slot count, flash vs lazy mixer strategies, over a mixed
prompt/output-length request stream.

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]

Emits experiments/bench/BENCH_serving.json (normalized
{bench, machine, config, series} schema; one series entry per
(strategy, n_slots) cell) plus the usual CSV.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.serving import make_server

from benchmarks.common import serving_requests, write_bench_json, write_csv


def run_cell(cfg, params, *, strategy, n_slots, n_reqs, prompt_max, gen_max):
    srv = make_server(cfg, params, n_slots=n_slots, prompt_max=prompt_max,
                      gen_max=gen_max, strategy=strategy)
    for r in serving_requests(cfg, n_reqs, prompt_max, gen_max):
        srv.submit(r)
    # warm-up pass compiles the red step + per-(tile-side, prompt-length)
    # specializations; a second identical stream is then timed.
    srv.run()
    for r in serving_requests(cfg, n_reqs, prompt_max, gen_max):
        srv.submit(r)
    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    return {"strategy": strategy, "n_slots": n_slots, "tokens": toks,
            "seconds": round(dt, 4), "tok_s": round(toks / dt, 2)}


def main(smoke: bool = False, n_ops: int = 2, d_model: int = 64,
         slot_counts=(1, 2, 4)) -> str:
    cfg = dataclasses.replace(
        get_config("hyena").smoke(), name="hyena-serve-bench",
        n_layers=2 * n_ops, d_model=d_model, d_ff=2 * d_model, vocab=512)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    prompt_max, gen_max = (4, 8) if smoke else (8, 32)
    n_reqs = 6 if smoke else 16
    if smoke:
        slot_counts = tuple(slot_counts)[:2]

    records = []
    strategies = ("flash", "lazy")
    for strategy in strategies:
        for n_slots in slot_counts:
            rec = run_cell(cfg, params, strategy=strategy, n_slots=n_slots,
                           n_reqs=n_reqs, prompt_max=prompt_max,
                           gen_max=gen_max)
            records.append(rec)
            print(f"[bench_serving] {strategy:6s} slots={n_slots}: "
                  f"{rec['tokens']} tok in {rec['seconds']:.2f}s  "
                  f"{rec['tok_s']:8.1f} tok/s")

    path = write_bench_json(
        "serving",
        {"arch": cfg.name, "family": cfg.family, "n_requests": n_reqs,
         "prompt_max": prompt_max, "gen_max": gen_max,
         "slot_counts": list(slot_counts), "strategies": list(strategies)},
        records, smoke=smoke)
    write_csv("serving_smoke" if smoke else "serving",
              ["strategy", "n_slots", "tokens", "seconds", "tok_per_s"],
              [[r["strategy"], r["n_slots"], r["tokens"], r["seconds"],
                r["tok_s"]] for r in records])
    print(f"[bench_serving] wrote {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream (CI-sized)")
    args = ap.parse_args()
    main(smoke=args.smoke)
