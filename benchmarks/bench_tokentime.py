"""Per-token response time (paper Fig. 2c): flash shows flat latency with
rare spikes exactly at the large-tile positions (93.75 % of steps use
U ≤ 8), vs the monotonically growing lazy/eager per-token cost."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.engine import FlashEngine
from repro.core.tiling import largest_pow2_divisor
from repro.models.synthetic_lcsm import SyntheticLCSM

from benchmarks.common import write_csv


def per_token_times(strategy: str, L: int, M: int = 3, D: int = 32):
    model = SyntheticLCSM(n_levels=M, d_model=D)
    params = model.init(jax.random.PRNGKey(0))
    eng = FlashEngine(model, params, batch=1, gen_max=L, strategy=strategy)

    def fresh():
        state = eng.init_state()
        return eng.set_first(
            state, jax.random.normal(jax.random.PRNGKey(1), (1, D)))

    # warm-up: run the whole schedule once so every per-U jit is compiled.
    # (The step functions DONATE their state, so the warmed-up state is dead
    # afterwards — rebuild for the timed loop.)
    warm, _ = eng.generate(fresh(), L, rng=jax.random.PRNGKey(2))
    jax.block_until_ready(warm.a[0])
    state = fresh()
    times = []
    rng = jax.random.PRNGKey(3)
    # Drive the engine's own per-step schedule skeleton (red pass + this
    # step's gray tile) so each sample times the token's REAL work — a
    # generate(1) call would never dispatch a tile (its 1-step schedule has
    # no next token).
    for step in range(L):
        t0 = time.perf_counter()
        pv = jnp.full((1,), step, jnp.int32)
        tile = None
        if strategy == "flash" and step + 1 < L:
            tile = lambda st, p=step: eng._gray_tile_guard(
                st, p, largest_pow2_divisor(p + 1))
        state, _, rng = eng._schedule_step(
            eng.params, state, pv, rng, tile, jitted=True)
        jax.block_until_ready(state.a[0])
        times.append(time.perf_counter() - t0)
    return times


def main(L: int = 256) -> str:
    tf = per_token_times("flash", L)
    tl = per_token_times("lazy", L)
    rows = [[i + 1, largest_pow2_divisor(i + 1), f"{tf[i] * 1e3:.3f}",
             f"{tl[i] * 1e3:.3f}"] for i in range(L)]
    path = write_csv("token_time", ["pos", "tile_U", "flash_ms", "lazy_ms"], rows)
    big = [t for i, t in enumerate(tf) if largest_pow2_divisor(i + 1) >= L // 4]
    small = [t for i, t in enumerate(tf) if largest_pow2_divisor(i + 1) <= 8]
    print(f"[bench_tokentime] flash median small-tile "
          f"{sorted(small)[len(small)//2]*1e3:.2f}ms; large-tile mean "
          f"{sum(big)/max(len(big),1)*1e3:.2f}ms (spikes are the paper's Fig 2c)")
    print(f"[bench_tokentime] wrote {path}")
    return path


if __name__ == "__main__":
    main()
