"""Benchmark orchestrator: one entry per paper table/figure + the roofline
report over whatever dry-run artifacts exist.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sweeps (CI-sized)")
    args = ap.parse_args()

    from benchmarks import (bench_e2e, bench_flops, bench_generic,
                            bench_mixer, bench_serving, bench_tau,
                            bench_tokentime, bench_traffic, roofline_report)

    jobs = [
        ("serving throughput (continuous batching)",
         lambda: bench_serving.main(smoke=args.fast)),
        ("traffic frontend (open-loop arrivals + prefix-cache sweep)",
         lambda: bench_traffic.main(smoke=args.fast)),
        ("generic engine, GLA flash vs recurrent (§4 'and Beyond')",
         lambda: bench_generic.main(smoke=args.fast)),
        ("flops (Prop 1/2, Thm 2)", lambda: bench_flops.main()),
        ("tau Pareto (Fig 3a/3b)", lambda: bench_tau.main(
            D=64 if args.fast else 128)),
        ("mixer scaling (Fig 2b)", lambda: bench_mixer.main(
            Ls=(64, 256) if args.fast else (256, 1024, 4096))),
        ("token time (Fig 2c)", lambda: bench_tokentime.main(
            L=64 if args.fast else 256)),
        ("e2e hyena (Fig 2a)", lambda: bench_e2e.main(
            L=64 if args.fast else 256)),
        ("roofline report (dry-run)", lambda: roofline_report.main()),
    ]
    failures = 0
    t0 = time.perf_counter()
    for name, fn in jobs:
        print(f"\n=== {name} ===")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc(limit=6)
    print(f"\n=== benchmarks done in {time.perf_counter() - t0:.1f}s, "
          f"{failures} failures ===")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
