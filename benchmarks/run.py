"""Benchmark orchestrator: one entry per paper table/figure + the roofline
report over whatever dry-run artifacts exist, fronted by the flashcheck
static-contract gate (python -m repro.staticcheck) so a tree that violates
the donation / dispatch / cache invariants never gets timed — its numbers
would not be comparable to the committed sweeps.

    PYTHONPATH=src python -m benchmarks.run [--fast]

flashcheck's machine-readable report lands in
experiments/staticcheck/report.json (same artifact convention as the
BENCH_*.json records); run it standalone with

    PYTHONPATH=src python -m repro.staticcheck src tests benchmarks \
        --fail-on-warn --json experiments/staticcheck/report.json
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def _staticcheck_gate() -> None:
    """Run the AST contract rules over the tree and drop the JSON report
    next to the benchmark artifacts.  Raises on any unsuppressed finding."""
    import json
    import os

    from repro.staticcheck import analyze, load_config

    root = os.path.join(os.path.dirname(__file__), "..")
    cwd = os.getcwd()
    os.chdir(root)
    try:
        report = analyze(["src", "tests", "benchmarks"],
                         load_config("staticcheck.toml"), jaxpr=False)
        out_dir = os.path.join("experiments", "staticcheck")
        os.makedirs(out_dir, exist_ok=True)
        out = os.path.join(out_dir, "report.json")
        with open(out, "w") as fh:
            json.dump(report.to_dict(), fh, indent=2, sort_keys=True)
        print(f"flashcheck: {report.files_scanned} files, "
              f"{len(report.live())} live finding(s) -> {out}")
        if report.failed(fail_on_warn=True):
            raise RuntimeError(
                "static contract violations:\n" +
                "\n".join(f.render() for f in report.live()))
    finally:
        os.chdir(cwd)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sweeps (CI-sized)")
    args = ap.parse_args()

    from benchmarks import (bench_e2e, bench_flops, bench_generic,
                            bench_mixer, bench_serving, bench_tau,
                            bench_tokentime, bench_traffic, roofline_report)

    jobs = [
        ("flashcheck static contracts (gate)", _staticcheck_gate),
        ("serving throughput (continuous batching)",
         lambda: bench_serving.main(smoke=args.fast)),
        ("traffic frontend (open-loop arrivals + prefix-cache sweep)",
         lambda: bench_traffic.main(smoke=args.fast)),
        ("generic engine, GLA flash vs recurrent (§4 'and Beyond')",
         lambda: bench_generic.main(smoke=args.fast)),
        ("flops (Prop 1/2, Thm 2)", lambda: bench_flops.main()),
        ("tau Pareto (Fig 3a/3b)", lambda: bench_tau.main(
            D=64 if args.fast else 128)),
        ("mixer scaling (Fig 2b)", lambda: bench_mixer.main(
            Ls=(64, 256) if args.fast else (256, 1024, 4096))),
        ("token time (Fig 2c)", lambda: bench_tokentime.main(
            L=64 if args.fast else 256)),
        ("e2e hyena (Fig 2a)", lambda: bench_e2e.main(
            L=64 if args.fast else 256)),
        ("roofline report (dry-run)", lambda: roofline_report.main()),
    ]
    failures = 0
    t0 = time.perf_counter()
    for name, fn in jobs:
        print(f"\n=== {name} ===")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc(limit=6)
    print(f"\n=== benchmarks done in {time.perf_counter() - t0:.1f}s, "
          f"{failures} failures ===")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
