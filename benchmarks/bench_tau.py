"""τ-implementation Pareto frontier (paper Figure 3a/3b analogue).

Times each τ implementation (direct einsum, FFT with precomputed filter
DFT, Pallas tile_conv in interpret mode) across tile sides U and reports
the per-U winner — the measurement that feeds the Hybrid dispatcher's
``direct_max`` crossover.  CPU wall-clock stands in for the paper's GPU
timings; the Pareto *structure* (direct wins small U, FFT wins large U)
is the hardware-independent claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tau as tau_mod
from repro.kernels import ops as kops

from benchmarks.common import timeit, write_csv


def main(D: int = 128, B: int = 4, M: int = 4) -> str:
    key = jax.random.PRNGKey(0)
    rows = []
    for q in range(0, 11):
        U = 1 << q
        y = jax.random.normal(key, (M, B, U, D), jnp.float32)
        rho = jax.random.normal(key, (M, 1, 2 * U, D), jnp.float32)
        rho_f = tau_mod.rho_dft(rho)

        t_direct = timeit(jax.jit(tau_mod.tau_direct), y, rho)
        t_fft = timeit(jax.jit(lambda y, rf: tau_mod.tau_fft(y, rho_f=rf)), y, rho_f)
        t_pallas = timeit(lambda y, r: kops.tile_conv(y, r), y, rho) \
            if U <= 64 else float("nan")
        best = min(("direct", t_direct), ("fft", t_fft),
                   key=lambda kv: kv[1])[0]
        rows.append([U, f"{t_direct * 1e6:.1f}", f"{t_fft * 1e6:.1f}",
                     f"{t_pallas * 1e6:.1f}" if t_pallas == t_pallas else "",
                     best])
        print(f"[bench_tau] U={U:5d}  direct {t_direct*1e6:9.1f}us  "
              f"fft {t_fft*1e6:9.1f}us  -> {best}")
    path = write_csv("tau_pareto", ["U", "direct_us", "fft_us",
                                    "pallas_interp_us", "winner"], rows)
    print(f"[bench_tau] wrote {path}")
    return path


if __name__ == "__main__":
    main()
