"""τ-implementation Pareto frontier (paper Figure 3a/3b analogue).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_tau [--smoke]

Times each τ implementation (direct einsum, FFT with precomputed filter
DFT, Pallas tile_conv in interpret mode) across tile sides U and reports
the per-U winner — the measurement that feeds the Hybrid dispatcher's
``direct_max`` crossover.  On top of the raw τ kernels it also times the
engine-level gray-tile step both ways (``gray_impl="xla"`` gather/τ/
scatter chain vs the fused Pallas ``gray_tile_apply``) so the fused
dispatch heuristic's ``FUSED_MAX_U`` ceiling is measured, not guessed.

CPU wall-clock stands in for the paper's GPU timings; the Pareto
*structure* (direct wins small U, FFT wins large U) is the
hardware-independent claim.

Cells that a sweep point deliberately does not measure (tile_conv beyond
its interpret-mode budget, fused gray beyond ``FUSED_MAX_U``) are emitted
as the explicit marker ``skipped`` — never a NaN compared against itself.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import tau as tau_mod
from repro.core.engine import FlashEngine
from repro.kernels import ops as kops
from repro.kernels.heuristic import FUSED_MAX_U
from repro.models.synthetic_lcsm import SyntheticLCSM

from benchmarks.common import timeit, write_bench_json, write_csv

SKIPPED = "skipped"  # explicit CSV marker for deliberately-unmeasured cells

# tile_conv runs in Pallas interpret mode on CPU — the per-element python
# dispatch makes large U pointlessly slow to time, so cap the sweep.
_PALLAS_MAX_U = 64


def _fmt_us(t: float | None) -> str:
    return SKIPPED if t is None else f"{t * 1e6:.1f}"


def _gray_engines(D: int, B: int, gen_max: int):
    """One synthetic-LCSM engine per gray_impl, sharing params."""
    model = SyntheticLCSM(n_levels=3, d_model=D)
    params = model.init(jax.random.PRNGKey(0))
    engs = {impl: FlashEngine(model, params, batch=B, gen_max=gen_max,
                              gray_impl=impl)
            for impl in ("xla", "pallas")}
    return engs


def _time_gray(eng, U: int) -> float:
    state = eng.init_state()
    key = jax.random.PRNGKey(U)
    a = tuple(jax.random.normal(jax.random.fold_in(key, i), x.shape, x.dtype)
              for i, x in enumerate(state.a))
    state = state._replace(a=a)
    p = jnp.full((eng.batch,), max(U - 1, eng.Lbuf // 2), jnp.int32)
    mask = jnp.ones((eng.batch,), bool)
    fn = jax.jit(lambda s, pp, mm: eng._gray_tile(None, s, pp, mm, U=U))
    return timeit(fn, state, p, mask)


def main(D: int = 128, B: int = 4, M: int = 4, smoke: bool = False) -> str:
    key = jax.random.PRNGKey(0)
    qs = range(0, 3) if smoke else range(0, 11)
    gray_gen_max = 16 if smoke else 256
    engs = _gray_engines(D=32 if smoke else D, B=B, gen_max=gray_gen_max)
    gray_max_u = engs["xla"].Lbuf // 2

    rows = []
    series: list[dict] = []

    def record(U: int, impl: str, seconds: float | None):
        if seconds is None:
            return
        tokens = M * B * U
        series.append({"U": U, "impl": impl, "tokens": tokens,
                       "seconds": seconds, "tok_s": tokens / seconds})

    for q in qs:
        U = 1 << q
        y = jax.random.normal(key, (M, B, U, D), jnp.float32)
        rho = jax.random.normal(key, (M, 1, 2 * U, D), jnp.float32)
        rho_f = tau_mod.rho_dft(rho)

        t_direct = timeit(jax.jit(tau_mod.tau_direct), y, rho)
        t_fft = timeit(jax.jit(lambda y, rf: tau_mod.tau_fft(y, rho_f=rf)),
                       y, rho_f)
        t_pallas = (timeit(lambda y, r: kops.tile_conv(y, r), y, rho)
                    if U <= _PALLAS_MAX_U else None)
        t_gray_xla = _time_gray(engs["xla"], U) if U <= gray_max_u else None
        t_gray_fused = (_time_gray(engs["pallas"], U)
                        if U <= min(gray_max_u, FUSED_MAX_U) else None)

        best = min(("direct", t_direct), ("fft", t_fft),
                   key=lambda kv: kv[1])[0]
        record(U, "direct", t_direct)
        record(U, "fft", t_fft)
        record(U, "pallas_interp", t_pallas)
        record(U, "gray_xla", t_gray_xla)
        record(U, "gray_fused_interp", t_gray_fused)
        rows.append([U, _fmt_us(t_direct), _fmt_us(t_fft), _fmt_us(t_pallas),
                     _fmt_us(t_gray_xla), _fmt_us(t_gray_fused), best])
        print(f"[bench_tau] U={U:5d}  direct {t_direct*1e6:9.1f}us  "
              f"fft {t_fft*1e6:9.1f}us  gray_xla(us) {_fmt_us(t_gray_xla):>9}  "
              f"gray_fused(us) {_fmt_us(t_gray_fused):>9}  -> {best}")

    # Largest U such that direct wins at every sweep point <= U: the
    # measured §5.3 crossover that ``direct_max`` should be set to.
    crossover = 0
    for row in rows:
        if row[-1] != "direct":
            break
        crossover = row[0]

    csv_path = write_csv(
        "tau_pareto_smoke" if smoke else "tau_pareto",
        ["U", "direct_us", "fft_us", "pallas_interp_us",
         "gray_xla_us", "gray_fused_interp_us", "winner"], rows)
    json_path = write_bench_json(
        "tau",
        {"D": D, "B": B, "M": M, "U_sweep": [1 << q for q in qs],
         "fused_max_u": FUSED_MAX_U, "gray_gen_max": gray_gen_max,
         "measured_direct_crossover": crossover,
         "interpret_mode": jax.default_backend() != "tpu"},
        series, smoke=smoke)
    print(f"[bench_tau] direct/fft crossover at U={crossover}")
    print(f"[bench_tau] wrote {csv_path}")
    print(f"[bench_tau] wrote {json_path}")
    return json_path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized run")
    args = ap.parse_args()
    main(smoke=args.smoke)
