"""Paper Propositions 1–2 / Theorem 2: analytic FLOP + data-movement counts.

Validates (structurally, hardware-independent):
  * tile histogram: 2^(P-1-q) tiles of side 2^q  (Prop. 1)
  * total τ cost Σ 2^(P-1-q)·T(2^q,2^q) = O(L log² L) vs Ω(L²) naive
  * activation positions touched O(L log L) vs Ω(L²)  (§3.3)
  * 93.75 % of steps use tile side U ≤ 8  (§5.1)
"""

from __future__ import annotations

from repro.core import tiling

from benchmarks.common import write_csv


def main() -> list[str]:
    rows = []
    for P in range(8, 17):
        L = 1 << P
        fft = tiling.theoretical_tau_flops(L, impl="fft")
        direct = tiling.theoretical_tau_flops(L, impl="direct")
        naive = tiling.naive_flops(L)
        touched = tiling.activation_positions_touched(L)
        rows.append([L, f"{fft:.3e}", f"{direct:.3e}", f"{naive:.3e}",
                     f"{naive / fft:.1f}", touched, L * (L - 1) // 2,
                     f"{L * (L - 1) / 2 / touched:.1f}"])
    path = write_csv("flops_model",
                     ["L", "flash_fft_flops", "flash_direct_flops",
                      "naive_flops", "flop_speedup", "act_touched_flash",
                      "act_touched_naive", "touch_reduction"], rows)

    hist = tiling.tile_histogram(1 << 12)
    hrows = [[u, n] for u, n in sorted(hist.items())]
    hpath = write_csv("tile_histogram_L4096", ["tile_side", "count"], hrows)

    small = sum(n for u, n in hist.items() if u <= 8) / sum(hist.values())
    print(f"[bench_flops] L=4096: {small:.4%} of steps use U<=8 "
          f"(paper claims 93.75%)")
    print(f"[bench_flops] wrote {path}\n[bench_flops] wrote {hpath}")
    return [path, hpath]


if __name__ == "__main__":
    main()
