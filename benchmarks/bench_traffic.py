"""Open-loop traffic benchmark for the serving frontend: seeded
Poisson-style arrivals with mixed prompt lengths, streamed delivery, and
a prefix-cache hit-rate sweep.

    PYTHONPATH=src python -m benchmarks.bench_traffic [--smoke]

Per (hit_frac, cache on/off) cell the scheduler serves the SAME arrival
trace; before any timing the cache-on streams are asserted token-identical
to the cache-off streams (the frontend's bitwise bar), then the timed run
reports tok/s plus the frontend's latency telemetry: mean/p95 TTFT, mean
queue depth, slot occupancy, and cache hit counts.  Emits
experiments/bench/BENCH_traffic.json (normalized
{bench, machine, config, series} schema) plus the usual CSV.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.serving import make_server
from repro.serving.frontend import (PrefixCache, TrafficScheduler,
                                    poisson_trace)

from benchmarks.common import write_bench_json, write_csv


def _serve(srv, vocab, *, prompt_max, gen_max, chunk, n_reqs, rate,
           hit_frac, cache: bool, seed=0):
    # a fresh scheduler per serve: metrics start at 0 and the timed run's
    # prefix cache starts cold (hits below are all intra-trace reuse)
    sched = TrafficScheduler(srv, chunk=chunk,
                             prefix_cache=PrefixCache() if cache else None)
    trace = poisson_trace(vocab, n_reqs, rate=rate,
                          prompt_max=prompt_max, gen_max=gen_max,
                          hit_frac=hit_frac, seed=seed)
    rep = sched.run(trace)
    streams = {tr.req.uid: tuple(tr.req.out) for tr in trace}
    return rep, streams


def run_cell(cfg, params, *, hit_frac, cache, n_slots, **kw):
    # warm-up pass compiles every prefill bucket / chunk program on the
    # SAME server instance (the engine's jit caches are per instance —
    # bench_serving protocol), then an identical cold-cache trace is timed.
    srv = make_server(cfg, params, n_slots=n_slots,
                      prompt_max=kw["prompt_max"], gen_max=kw["gen_max"])
    _serve(srv, cfg.vocab, hit_frac=hit_frac, cache=cache, **kw)
    rep, streams = _serve(srv, cfg.vocab, hit_frac=hit_frac, cache=cache,
                          **kw)
    m = rep.metrics
    return rep, streams, {
        "hit_frac": hit_frac,
        "cache": cache,
        "tokens": m["throughput"]["tokens"],
        "seconds": round(m["throughput"]["wall_s"], 4),
        "tok_s": round(m["throughput"]["tok_s"], 2),
        "ttft_mean_s": round(m["ttft_s"]["mean"], 5),
        "ttft_p95_s": round(m["ttft_s"]["p95"], 5),
        "token_gap_mean_s": round(m["token_gap_s"]["mean"], 6),
        "queue_depth_mean": round(m["queue_depth"]["mean"], 3),
        "slot_occupancy_mean": round(m["slot_occupancy"]["mean"], 3),
        "cache_hits": (rep.cache or {}).get("hits", 0),
        "completed": m["requests"]["completed"],
    }


def trace_run(cfg, params, kw, hit_frac, trace_out: str) -> str:
    """One traced cache-on chunked serve, exported as a Perfetto trace.

    Runs AFTER the timed sweep so flashtrace overhead (host-side only,
    but nonzero) never touches the reported numbers.  Chunked + prefix
    cache on: the trace then shows the dispatch-ahead overlap (chunk N+1's
    ``server.dispatch_chunk`` span landing before chunk N's
    ``server.collect_chunk``), per-side gray-tile counters, and
    prefix-cache hit/evict events — the spans README "Observability"
    documents."""
    from repro import obs

    rec = obs.enable_tracing()
    try:
        srv = make_server(cfg, params, n_slots=kw["n_slots"],
                          prompt_max=kw["prompt_max"], gen_max=kw["gen_max"])
        _serve(srv, cfg.vocab, hit_frac=hit_frac, cache=True,
               **{k: v for k, v in kw.items() if k != "n_slots"}
               | {"chunk": kw["chunk"] or 4})
        path = obs.write_trace_json(rec, trace_out)
    finally:
        obs.disable_tracing()
    print(f"[bench_traffic] wrote {path} (open at https://ui.perfetto.dev)")
    return path


def main(smoke: bool = False, trace_out: str | None = None) -> str:
    cfg = dataclasses.replace(
        get_config("hyena").smoke(), name="hyena-traffic-bench",
        n_layers=4, d_model=64, d_ff=128, vocab=512)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    kw = dict(n_slots=2 if smoke else 4,
              prompt_max=4 if smoke else 8,
              gen_max=8 if smoke else 32,
              chunk=None if smoke else 8,
              n_reqs=6 if smoke else 24,
              rate=0.5)
    hit_fracs = (0.0, 0.6) if smoke else (0.0, 0.5, 0.9)

    records = []
    identical = True
    for hf in hit_fracs:
        cold = hot = None
        for cache in (False, True):
            rep, streams, rec = run_cell(cfg, params, hit_frac=hf,
                                         cache=cache, **kw)
            if cache:
                hot = streams
            else:
                cold = streams
            records.append(rec)
            print(f"[bench_traffic] hit_frac={hf:.1f} cache={cache!s:5s}: "
                  f"{rec['tokens']} tok  {rec['tok_s']:8.1f} tok/s  "
                  f"ttft {rec['ttft_mean_s'] * 1e3:7.1f} ms  "
                  f"queue {rec['queue_depth_mean']:.2f}  "
                  f"hits {rec['cache_hits']}")
        if cold != hot:
            identical = False
    assert identical, "cache-on streams diverged from cache-off streams"

    path = write_bench_json(
        "traffic",
        {"arch": cfg.name, "family": cfg.family, **kw,
         "hit_fracs": list(hit_fracs),
         "streams_identical_with_cache": identical},
        records, smoke=smoke)
    write_csv("traffic_smoke" if smoke else "traffic",
              list(records[0].keys()),
              [list(r.values()) for r in records])
    print(f"[bench_traffic] wrote {path}")
    if trace_out:
        trace_run(cfg, params, kw, hit_fracs[-1], trace_out)
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI-sized)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="after the sweep, run one traced cache-on chunked "
                         "serve and write a Perfetto trace.json here")
    args = ap.parse_args()
    main(smoke=args.smoke, trace_out=args.trace_out)
