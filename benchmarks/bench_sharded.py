"""Sharded multi-device Flash-Inference serving: tok/s vs device count.

The serving mesh shards slots over a 'data' axis (``LCSMServer(mesh=...)``,
see launch/mesh.make_serving_mesh); every device advances its slot shard's
tile schedules concurrently — the paper's cross-layer gray-tile parallelism
at mesh scale.  This benchmark sweeps the data-axis size over one fixed
request trace and ALSO asserts the correctness bar along the way: every
per-request greedy stream must be identical on every mesh size.

Runs anywhere: if fewer real devices exist than the sweep needs, the host
platform is forced to 8 virtual devices (``XLA_FLAGS=
--xla_force_host_platform_device_count=8``) — that makes CPU CI exercise
the real sharded program, though CPU "devices" are threads sharing one
socket, so tok/s there measures dispatch overhead, not hardware scaling.

    PYTHONPATH=src python -m benchmarks.bench_sharded [--smoke]

Emits experiments/bench/BENCH_sharded.json (normalized
{bench, machine, config, series} schema) plus the usual CSV.
"""

from __future__ import annotations

import argparse
import os


def _force_host_devices(n: int = 8) -> None:
    """Must run BEFORE jax is imported anywhere in this process."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


if __name__ == "__main__":  # only force when run as the entry point
    _force_host_devices()

import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import make_serving_mesh  # noqa: E402
from repro.models.hyena import HyenaLCSM  # noqa: E402
from repro.serving import make_server  # noqa: E402

from benchmarks.common import (  # noqa: E402
    serving_requests, write_bench_json, write_csv)


def run_cell(cfg, params, *, n_devices, n_slots, n_reqs, prompt_max,
             gen_max, chunk):
    mesh = make_serving_mesh(data=n_devices) if n_devices else None
    srv = make_server(cfg, params, n_slots=n_slots, prompt_max=prompt_max,
                      gen_max=gen_max, chunk=chunk, mesh=mesh)
    for r in serving_requests(cfg, n_reqs, prompt_max, gen_max):
        srv.submit(r)
    srv.run()  # warm-up: compiles every per-mesh program specialization
    reqs = serving_requests(cfg, n_reqs, prompt_max, gen_max)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    streams = {r.uid: tuple(r.out) for r in reqs}
    return {"devices": n_devices or 1, "n_slots": n_slots, "tokens": toks,
            "seconds": round(dt, 4), "tok_s": round(toks / dt, 2)}, streams


def main(smoke: bool = False) -> str:
    cfg = dataclasses.replace(
        get_config("hyena").smoke(), name="hyena-sharded-bench",
        n_layers=4, d_model=32 if smoke else 64,
        d_ff=64 if smoke else 128, vocab=256)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    prompt_max, gen_max = (4, 8) if smoke else (8, 32)
    n_reqs = 6 if smoke else 16
    chunk = 4
    avail = jax.device_count()
    counts = [n for n in (1, 2, 4, 8) if n <= avail]
    if smoke:
        counts = counts[:2]
    n_slots = max(counts) * 2  # >= 2 slot rows per device on every mesh

    records, ref_streams = [], None
    for n in counts:
        rec, streams = run_cell(cfg, params, n_devices=n, n_slots=n_slots,
                                n_reqs=n_reqs, prompt_max=prompt_max,
                                gen_max=gen_max, chunk=chunk)
        # correctness gate: sharding must not change a single token.
        if ref_streams is None:
            ref_streams = streams
        assert streams == ref_streams, (
            f"greedy streams diverged on the {n}-device mesh")
        records.append(rec)
        print(f"[bench_sharded] devices={n}: {rec['tokens']} tok in "
              f"{rec['seconds']:.2f}s  {rec['tok_s']:8.1f} tok/s")

    path = write_bench_json(
        "sharded",
        {"arch": cfg.name, "family": cfg.family, "n_requests": n_reqs,
         "prompt_max": prompt_max, "gen_max": gen_max, "n_slots": n_slots,
         "chunk": chunk, "device_counts": counts,
         "streams_identical_across_meshes": True},
        records, smoke=smoke)
    write_csv("sharded_smoke" if smoke else "sharded",
              ["devices", "n_slots", "tokens", "seconds", "tok_s"],
              [[r["devices"], r["n_slots"], r["tokens"], r["seconds"],
                r["tok_s"]] for r in records])
    print(f"[bench_sharded] wrote {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized run")
    args = ap.parse_args()
    main(smoke=args.smoke)
