"""Multi-device Flash-Inference serving: scale-out tok/s vs device count,
with bitwise stream gates and per-chunk dispatch accounting.

The headline sweep is WEAK SCALING, which is how scale-out serving is
actually deployed: the per-device resources (2 slots) and per-device
traffic (the same 16-request mix) are held fixed, and the device count
N = 1 -> 2 -> 4 -> 8 serves N copies of that mix behind one frontend.
Devices > 1 use the replica layout (``make_server(replicas=N)``, N
independent single-device servers with frontend request routing and
dispatch-ahead interleaving — no collectives); N = 1 is the plain
single-device server the replica layout degenerates to.

Every cell serves through the traffic frontend with a SHARED
device-resident prefix cache (serving/frontend/prefix_cache): the first
copy of each prompt pays the prefill, every later copy — on any replica —
restores the post-prefill rows from the cache.  Aggregate throughput
therefore rises with the device count for a structural reason (prefill
amortization across the fleet) that survives even on hosts where the
"devices" are virtual: when fewer real devices exist than the sweep
needs, the host platform is forced to 8 virtual devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``), which exercises
the real replicated programs but time-shares one socket, so raw
compute does not parallelize there — the measured scaling signal is the
work the cache and the batched dispatch remove, not hardware FLOPs.

Correctness comes before timing.  The fixed 16-request mix is first
decoded on the single-device server under the retired cond-ladder
reference dispatch (``server_dispatch="reference"``) to produce oracle
streams, and the bench asserts bitwise-identical greedy streams for:

* the batched gather/scatter dispatch on one device (vs-reference gate),
* the GSPMD mesh layout at data=2 and data=4 (across-meshes gate),
* every copy served by every replica cell, cache hits included
  (across-replicas gate, checked on the warm-up drain before the timed
  trials and again on the timed drain itself).

Each sweep cell also reports ``dispatches`` (host->XLA program launches
during the timed drain, summed over members'
``ScheduleWalker.dispatch_count``) and ``dispatches_per_chunk``
(dispatches per fused K-token chunk round; admission prefills are the
overhead above 1.0) — the quantity the batched-dispatch refactor exists
to shrink and the number to watch when a layout anti-scales.

    PYTHONPATH=src python -m benchmarks.bench_sharded [--smoke]

Emits experiments/bench/BENCH_sharded.json (normalized
{bench, machine, config, series} schema) plus the usual CSV.
tests/test_bench_schema.py pins the schema AND the monotone
non-decreasing tok/s of the committed sweep.
"""

from __future__ import annotations

import argparse
import gc
import os


def _force_host_devices(n: int = 8) -> None:
    """Must run BEFORE jax is imported anywhere in this process."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip())


if __name__ == "__main__":  # only force when run as the entry point
    _force_host_devices()

import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import make_serving_mesh  # noqa: E402
from repro.models.hyena import HyenaLCSM  # noqa: E402
from repro.serving import Request, make_server  # noqa: E402
from repro.serving.frontend import TrafficRequest, make_frontend  # noqa: E402

from benchmarks.common import write_bench_json, write_csv  # noqa: E402

CACHE_BYTES = 1 << 28  # shared prefix cache: ample, never-evicting budget


def _engines(srv):
    """The engine(s) behind a server: one for mesh/single layouts, one per
    member for a ReplicaSet."""
    if hasattr(srv, "members"):
        return [m.engine for m in srv.members]
    return [srv.engine]


class _ChunkCounter:
    """Counts fused chunk rounds by wrapping each engine's
    ``server_chunk`` (host-side bookkeeping only — the jitted programs are
    untouched)."""

    def __init__(self, srv):
        self.rounds = 0
        for eng in _engines(srv):
            orig = eng.server_chunk

            def counted(*a, _orig=orig, **kw):
                self.rounds += 1
                return _orig(*a, **kw)

            eng.server_chunk = counted


def base_mix(cfg, n_reqs: int, prompt_max: int, gen_max: int,
             seed: int = 0) -> list[tuple[np.ndarray, int]]:
    """The fixed per-device request mix: prompts uniform in
    [prompt_max/2, prompt_max], outputs in [gen_max/2, gen_max] — the
    long-shared-prompt / short-output shape (classification, extraction,
    system-prompted chat turns) that prefix-cached serving exists for."""
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab,
                         (int(rng.randint(prompt_max // 2, prompt_max + 1)),)
                         ).astype(np.int32),
             int(rng.randint(gen_max // 2, gen_max + 1)))
            for _ in range(n_reqs)]


def _requests(mix) -> list[Request]:
    return [Request(uid=i, prompt=p, max_new=m)
            for i, (p, m) in enumerate(mix)]


def _trace(mix, n_copies: int) -> list[TrafficRequest]:
    """``n_copies`` interleaved copies of the mix (copy-major order, so
    copy 0 is admitted first and seeds the cache), distinct uids."""
    n = len(mix)
    return [TrafficRequest(req=Request(uid=c * n + i, prompt=p, max_new=m))
            for c in range(n_copies) for i, (p, m) in enumerate(mix)]


def gate_streams(cfg, params, mix, *, prompt_max, gen_max, chunk,
                 mesh_data=None, dispatch="batched") -> dict[int, tuple]:
    """Drain the mix once on a throwaway server and return {uid: stream}.
    Untimed — these runs only exist to pin the bitwise contract."""
    mesh = make_serving_mesh(data=mesh_data) if mesh_data else None
    srv = make_server(cfg, params, n_slots=4, prompt_max=prompt_max,
                      gen_max=gen_max, chunk=chunk, mesh=mesh)
    srv.engine.server_dispatch = dispatch
    for r in _requests(mix):
        srv.submit(r)
    return {r.uid: tuple(r.out) for r in srv.run()}


def run_cell(cfg, params, mix, oracle, *, n_devices, n_slots, prompt_max,
             gen_max, chunk, trials=3):
    """One device-count cell of the weak-scaling sweep: N copies of the
    mix on N replicas (``n_slots`` slots EACH) behind a shared prefix
    cache.  Two warm-up drains (compiles; replica routing is
    load-dependent, so one drain can miss a prompt-length/member
    combination), a stream-identity check, then best-of-``trials`` timed
    drains, each against a fresh cache (cold-start hit pattern)."""
    layout = "replicas" if n_devices > 1 else "single"
    srv = make_server(cfg, params, n_slots=n_slots, prompt_max=prompt_max,
                      gen_max=gen_max, chunk=chunk,
                      **({"replicas": n_devices} if n_devices > 1 else {}))

    def drain():
        sched = make_frontend(srv, prefix_cache_bytes=CACHE_BYTES,
                              chunk=chunk)
        gc.collect()
        gc.disable()
        t0 = time.perf_counter()
        rep = sched.run(_trace(mix, n_devices))
        dt = time.perf_counter() - t0
        gc.enable()
        return rep, dt

    def check(rep):
        for tr in rep.trace:
            assert tuple(tr.req.out) == oracle[tr.req.uid % len(mix)], (
                f"stream diverged: uid {tr.req.uid}, {n_devices} devices")

    drain()
    rep, _ = drain()
    check(rep)  # bitwise gate BEFORE the timed trials (warm path, hits incl.)
    counter = _ChunkCounter(srv)
    best = None
    for _ in range(trials):
        counter.rounds = 0
        d0 = sum(eng.dispatch_count for eng in _engines(srv))
        rep, dt = drain()
        dispatches = sum(eng.dispatch_count for eng in _engines(srv)) - d0
        if best is None or dt < best[1]:
            best = (rep, dt, dispatches, counter.rounds)
    rep, dt, dispatches, rounds = best
    check(rep)  # and the drain the committed numbers come from
    toks = sum(len(tr.req.out) for tr in rep.trace)
    return {"layout": layout, "dispatch": "batched", "devices": n_devices,
            "n_slots_per_device": n_slots,
            "n_requests": n_devices * len(mix), "tokens": toks,
            "seconds": round(dt, 4), "tok_s": round(toks / dt, 2),
            "cache_hits": rep.cache["hits"],
            "dispatches": dispatches,
            "dispatches_per_chunk": round(dispatches / max(rounds, 1), 2)}


def main(smoke: bool = False) -> str:
    prompt_max, gen_max = (8, 8) if smoke else (32, 8)
    cfg = dataclasses.replace(
        get_config("hyena").smoke(), name="hyena-sharded-bench",
        n_layers=4, d_model=32 if smoke else 64,
        d_ff=64 if smoke else 128, vocab=256)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    n_base = 6 if smoke else 16
    chunk, slots_per_device = 4, 4
    avail = jax.device_count()
    counts = [n for n in (1, 2, 4, 8) if n <= avail]
    if smoke:
        counts = counts[:2]
    mesh_gates = [n for n in (2, 4) if n <= avail][:1 if smoke else 2]

    mix = base_mix(cfg, n_base, prompt_max, gen_max)
    gate_kw = dict(prompt_max=prompt_max, gen_max=gen_max, chunk=chunk)

    # --- bitwise gates (untimed, before any measurement) -----------------
    oracle = gate_streams(cfg, params, mix, dispatch="reference", **gate_kw)
    assert gate_streams(cfg, params, mix, **gate_kw) == oracle, (
        "batched dispatch diverged from the cond-ladder reference")
    for n in mesh_gates:
        assert gate_streams(cfg, params, mix, mesh_data=n, **gate_kw) \
            == oracle, f"data={n} mesh diverged from the reference streams"
    print(f"[bench_sharded] gates OK: batched==reference, "
          f"mesh data={mesh_gates} identical on {len(mix)} streams")

    # --- the weak-scaling sweep ------------------------------------------
    records = []
    for n in counts:
        rec = run_cell(cfg, params, mix, oracle, n_devices=n,
                       n_slots=slots_per_device, prompt_max=prompt_max,
                       gen_max=gen_max, chunk=chunk,
                       trials=1 if smoke else 5)
        records.append(rec)
        print(f"[bench_sharded] {rec['layout']:8s} devices={n}: "
              f"{rec['tokens']} tok in {rec['seconds']:.3f}s "
              f"{rec['tok_s']:8.1f} tok/s  hits {rec['cache_hits']}"
              f"/{rec['n_requests']}  "
              f"{rec['dispatches_per_chunk']:.2f} disp/chunk")

    path = write_bench_json(
        "sharded",
        {"arch": cfg.name, "family": cfg.family, "weak_scaling": True,
         "n_requests_per_device": n_base,
         "n_slots_per_device": slots_per_device,
         "prompt_max": prompt_max, "gen_max": gen_max, "chunk": chunk,
         "device_counts": counts, "layouts": ["single", "replicas"],
         "shared_prefix_cache_bytes": CACHE_BYTES,
         "timing": "best of 5 full drains, fresh cache per drain",
         "mesh_gate_device_counts": mesh_gates,
         "streams_identical_across_meshes": True,
         "streams_identical_across_replicas": True,
         "streams_identical_vs_reference_dispatch": True},
        records, smoke=smoke)
    write_csv("sharded_smoke" if smoke else "sharded",
              ["layout", "devices", "n_slots_per_device", "n_requests",
               "tokens", "seconds", "tok_s", "cache_hits", "dispatches",
               "dispatches_per_chunk"],
              [[r["layout"], r["devices"], r["n_slots_per_device"],
                r["n_requests"], r["tokens"], r["seconds"], r["tok_s"],
                r["cache_hits"], r["dispatches"],
                r["dispatches_per_chunk"]] for r in records])
    print(f"[bench_sharded] wrote {path}")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny CI-sized run")
    args = ap.parse_args()
    main(smoke=args.smoke)
