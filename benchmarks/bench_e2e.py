"""End-to-end Hyena inference: Flash vs lazy vs eager (paper Fig. 2a),
on the real Hyena architecture (reduced scale for CPU) through the full
serving path (embedding, operators, sampling)."""

from __future__ import annotations

import time

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.serving import LCSMServer

from benchmarks.common import write_csv


def main(L: int = 256, n_ops: int = 2, d_model: int = 64) -> str:
    cfg = dataclasses.replace(
        get_config("hyena").smoke(), name="hyena-bench",
        n_layers=2 * n_ops, d_model=d_model, d_ff=2 * d_model, vocab=512)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    rows = []
    outs = {}
    for strategy in ("flash", "lazy", "eager"):
        srv = LCSMServer(cfg, params, batch=1, gen_max=L, strategy=strategy)
        srv.generate(None, L)  # warm-up: full schedule compiles
        t0 = time.perf_counter()
        toks = srv.generate(None, L)
        dt = time.perf_counter() - t0
        outs[strategy] = toks
        rows.append([strategy, L, f"{dt:.3f}", f"{L / dt:.1f}"])
        print(f"[bench_e2e] {strategy:6s} L={L}: {dt:7.3f}s  {L/dt:7.1f} tok/s")
    # exactness across strategies (the paper's core claim)
    assert np.array_equal(outs["flash"], outs["lazy"]), "flash != lazy tokens!"
    assert np.array_equal(outs["flash"], outs["eager"]), "flash != eager tokens!"
    print("[bench_e2e] token streams identical across strategies (exact inference)")
    path = write_csv("e2e_hyena", ["strategy", "L", "seconds", "tok_per_s"], rows)
    print(f"[bench_e2e] wrote {path}")
    return path


if __name__ == "__main__":
    main()
