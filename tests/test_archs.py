"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each assigned family and run one forward/train step and
one decode step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models.lm import LM


def _batch(cfg, B=2, T=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab),
        "targets": jax.random.randint(ks[1], (B, T), 0, cfg.vocab),
    }
    if cfg.enc_layers:
        batch["enc_frames"] = jax.random.normal(
            ks[2], (B, cfg.enc_positions, cfg.d_model), jnp.float32)
    if cfg.m_rope:
        n_vis = 4
        batch["vis_embed"] = jax.random.normal(
            ks[3], (B, n_vis, cfg.d_model), jnp.float32) * 0.02
        batch["pos3"] = jnp.broadcast_to(
            jnp.arange(T + n_vis)[None, None], (3, B, T + n_vis))
    return batch


@pytest.mark.parametrize("name", ASSIGNED)
def test_smoke_train_step(name):
    cfg = get_config(name).smoke()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    gnorm = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x))), grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0, f"{name}: bad grads"


@pytest.mark.parametrize("name", ASSIGNED)
def test_smoke_decode_step(name):
    cfg = get_config(name).smoke()
    if cfg.family == "lcsm":
        pytest.skip("lcsm decode covered by engine tests")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    # f32 caches: the CPU backend can't execute bf16×bf16→f32 dots
    # (TPU serving uses bf16; the dry-run compiles that path).
    caches = model.init_caches(B, S, enc_S=cfg.enc_positions, dtype=jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos3 = jnp.zeros((3, B, 1), jnp.int32) if cfg.m_rope else None
    logits, caches = model.decode_step(params, tok, caches, pos3=pos3)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # second step must also work (cache threading)
    logits2, _ = model.decode_step(params, tok, caches, pos3=pos3)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("name", ASSIGNED)
def test_smoke_prefill_matches_decode(name):
    """Prefill then one decode step == forward over the extended sequence
    (the KV/state cache must be exact, not approximate)."""
    cfg = get_config(name).smoke()
    if cfg.family == "lcsm":
        pytest.skip("lcsm covered by engine tests")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T, S = 2, 8, 16
    batch = _batch(cfg, B=B, T=T)
    last_logits, caches = model.prefill(params, batch, S, cache_dtype=jnp.float32)

    nxt = jnp.argmax(last_logits, -1).astype(jnp.int32)[:, None]
    pos3 = (jnp.full((3, B, 1), T + (4 if cfg.m_rope else 0), jnp.int32)
            if cfg.m_rope else None)
    step_logits, _ = model.decode_step(params, nxt, caches, pos3=pos3)

    # reference: full forward over tokens + next token
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    if cfg.m_rope:
        n_vis = batch["vis_embed"].shape[1]
        batch2["pos3"] = jnp.broadcast_to(
            jnp.arange(T + n_vis + 1)[None, None], (3, B, T + n_vis + 1))
    hidden, _ = model.forward(params, batch2)
    ref_logits = model.logits(params, hidden[:, -1])
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32), np.asarray(ref_logits, np.float32),
        rtol=2e-2, atol=2e-2)


def test_hyena_engine_matches_static_forward():
    """The paper's exactness claim at the full-model level: FlashEngine
    decode over the hyena arch reproduces the static FFT forward."""
    from repro.core.engine import FlashEngine
    from repro.models.hyena import HyenaLCSM

    cfg = get_config("hyena").smoke()
    model = HyenaLCSM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, n = 2, 16
    eng = FlashEngine(model, params, batch=B, gen_max=n, strategy="flash")
    state = eng.init_state()
    tok0 = jnp.zeros((B,), jnp.int32)
    e = params["emb"][tok0]
    state = eng.set_first(state, model.embed_entry(params, e))
    state, toks = eng.generate(state, n, rng=jax.random.PRNGKey(1))

    # replay: embed the emitted token stream through the static path and
    # compare final activations
    a0 = state.a[0][:, :n]
    ref = eng.forward_static(a0)
    for l in range(1, len(ref)):
        np.testing.assert_allclose(
            np.asarray(state.a[l][:, :n]), np.asarray(ref[l]),
            rtol=2e-3, atol=2e-3)


def test_all_configs_registered():
    from repro.configs import list_configs
    names = list_configs()
    assert len([n for n in names if not n.endswith("smoke")]) >= 11
    for n in ASSIGNED:
        assert n in names
