"""flashcheck analyzer tests.

Three layers:

* **fixture corpus** — every rule FC001–FC006 has a bad fixture whose
  violations are marked with a trailing ``# FC00x`` comment and a clean
  twin exercising the hardened idioms.  The test derives the expected
  (rule, line) set from the markers, so fixtures stay self-documenting,
  and asserts zero findings on the twins (false-positive pin).
* **self-run** — the live repo is clean modulo the committed
  staticcheck.toml baseline, under ``--fail-on-warn`` semantics.
* **jaxpr pass** — the registered hot entry points satisfy the
  donation / cond-free / one-split-per-step contracts in-process, and
  (subprocess) under the forced-4-device mesh config.
"""

from __future__ import annotations

import ast
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.staticcheck import Config, Module, analyze, load_config, run_rules

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "staticcheck"

# fixture file -> (path to mount it at, rule under test).  FC003 only
# applies to the pinned mixer modules and FC005's lru_cache arm / FC006
# only to src/ / tests/, so fixtures are mounted at representative paths.
CASES = {
    "fc001": ("src/repro/fixture_fc001.py", "FC001"),
    "fc002": ("src/repro/fixture_fc002.py", "FC002"),
    "fc003": ("src/repro/models/gla.py", "FC003"),
    "fc004": ("src/repro/fixture_fc004.py", "FC004"),
    "fc005": ("src/repro/fixture_fc005.py", "FC005"),
    "fc006": ("tests/fixture_fc006.py", "FC006"),
    "fc007": ("src/repro/fixture_fc007.py", "FC007"),
}


def _run_fixture(name: str, mount: str):
    src = (FIXTURES / name).read_text()
    mod = Module(path=mount, tree=ast.parse(src))
    return src, run_rules([mod], Config())


def _marked_lines(src: str, rule: str) -> set[int]:
    return {i for i, line in enumerate(src.splitlines(), 1)
            if f"# {rule}" in line}


@pytest.mark.parametrize("stem", sorted(CASES))
def test_bad_fixture_exact_hits(stem):
    """Bad fixtures: the finding set is EXACTLY the marked (rule, line)s."""
    mount, rule = CASES[stem]
    src, findings = _run_fixture(f"{stem}_bad.py", mount)
    got = {(f.rule, f.line) for f in findings}
    want = {(rule, ln) for ln in _marked_lines(src, rule)}
    assert want, f"{stem}_bad.py has no # {rule} markers"
    assert got == want, f"{stem}: got {sorted(got)}, want {sorted(want)}"


@pytest.mark.parametrize("stem", sorted(CASES))
def test_good_fixture_zero_false_positives(stem):
    mount, rule = CASES[stem]
    _, findings = _run_fixture(f"{stem}_good.py", mount)
    assert findings == [], [f.render() for f in findings]


def test_fc007_obs_module_reachable():
    """The obs-path arm of FC007 needs two modules: a traced body in core
    reaching a function DEFINED under src/repro/obs/ is flagged even when
    the body itself contains no callback call."""
    walker = ast.parse(
        "class W:\n"
        "    def _red_pass(self, params, state, p, rng):\n"
        "        return obs_helper(state)\n")
    helper = ast.parse("def obs_helper(state):\n    return state\n")
    findings = run_rules(
        [Module(path="src/repro/core/x.py", tree=walker),
         Module(path="src/repro/obs/helper.py", tree=helper)], Config())
    assert any(f.rule == "FC007" and f.path == "src/repro/obs/helper.py"
               for f in findings), [f.render() for f in findings]


# ------------------------------------------------------------- suppressions
def test_suppression_requires_reason(tmp_path):
    p = tmp_path / "staticcheck.toml"
    p.write_text('[[suppress]]\nrule = "FC003"\npath = "x.py"\n')
    with pytest.raises(ValueError, match="reason"):
        load_config(p)


def test_suppression_matching(tmp_path):
    p = tmp_path / "staticcheck.toml"
    p.write_text(
        '[[suppress]]\nrule = "FC003"\npath = "src/repro/models/gla.py"\n'
        'symbol = "logits"\nreason = "documented"\n')
    cfg = load_config(p)
    assert cfg.suppression_for("FC003", "src/repro/models/gla.py",
                               "logits") == "documented"
    assert cfg.suppression_for("FC003", "src/repro/models/gla.py",
                               "read") == ""
    assert cfg.suppression_for("FC001", "src/repro/models/gla.py",
                               "logits") == ""


def test_suppressed_findings_dont_fail(tmp_path):
    p = tmp_path / "staticcheck.toml"
    p.write_text(
        '[[suppress]]\nrule = "FC003"\npath = "src/repro/models/gla.py"\n'
        'reason = "pinned elsewhere"\n')
    cfg = load_config(p)
    src = (FIXTURES / "fc003_bad.py").read_text()
    mod = Module(path="src/repro/models/gla.py", tree=ast.parse(src))
    findings = run_rules([mod], cfg)
    assert findings and all(f.suppressed for f in findings)


# ------------------------------------------------------------------ self-run
def test_live_repo_clean_modulo_baseline(monkeypatch):
    """`python -m repro.staticcheck src tests benchmarks --fail-on-warn`
    semantics on the live tree: zero unsuppressed findings."""
    monkeypatch.chdir(REPO)
    report = analyze(["src", "tests", "benchmarks"],
                     load_config(REPO / "staticcheck.toml"), jaxpr=False)
    assert report.files_scanned > 50
    assert report.live() == [], [f.render() for f in report.live()]
    assert not report.failed(fail_on_warn=True)
    # the committed baseline is neither empty nor stale: every suppression
    # suppresses something that the analyzer still finds.
    assert sum(1 for f in report.findings if f.suppressed) == len(
        load_config(REPO / "staticcheck.toml").suppressions)


def test_json_report_shape(monkeypatch):
    monkeypatch.chdir(REPO)
    report = analyze(["src/repro/staticcheck"],
                     load_config(REPO / "staticcheck.toml"), jaxpr=False)
    payload = json.loads(json.dumps(report.to_dict()))
    assert payload["tool"] == "flashcheck"
    assert set(payload["counts"]) >= {"files_scanned", "findings",
                                      "suppressed", "by_rule"}


# ---------------------------------------------------------------- jaxpr pass
EXPECTED_ENTRIES = {
    "FlashEngine.decode_chunk",
    "FlashEngine.server_chunk[batched]",
    "FlashEngine.prefill_slot",
    "FlashEngine[gray_impl=pallas].decode_chunk",
    "FlashEngine[gray_impl=pallas].server_chunk[batched]",
    "FlashEngine[gray_impl=pallas].prefill_slot",
    "GenericFlashEngine.server_chunk[batched]",
    "GenericFlashEngine.prefill_slot",
    "flashtrace.trace_invariance",
}


def test_jaxpr_pass_contracts():
    """Donation aliasing + cond-free batched dispatch + one-split-per-step
    hold on every registered hot entry point under the current devices."""
    from repro.staticcheck.jaxpr_pass import run_jaxpr_pass

    verdicts = run_jaxpr_pass()
    by_entry = {}
    for v in verdicts:
        by_entry.setdefault(v["entry"], []).append(v)
    assert set(by_entry) >= EXPECTED_ENTRIES
    bad = [v for v in verdicts if not v["ok"]]
    assert not bad, json.dumps(bad, indent=2, default=str)
    # the positive control proves the cond counter sees conds at all
    flash_server = by_entry["FlashEngine.server_chunk[batched]"][0]
    names = {c["name"]: c for c in flash_server["checks"]}
    assert names["reference_ladder_has_conds"]["ok"]


def test_jaxpr_pass_forced_4dev_subprocess():
    """The mesh-sensitive leg: under 4 forced host devices the LCSM engine
    is additionally traced on a 4-way data mesh and donation must still
    hold (buffer_donor markers + concrete deletion)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.staticcheck", "--jaxpr-only"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "mesh=data4" in proc.stdout
    assert "FAIL" not in proc.stdout
