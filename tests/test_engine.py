"""Engine exactness: Flash Inference (Alg. 2/3) must be bit-wise the same
computation as the lazy/eager O(L^2) baselines and the static (training-time)
forward pass — the paper's central claim is *exact* inference, not an
approximation (contrast with the Laughing-Hyena distillation, §2.3.2)."""

import jax
import numpy as np
import pytest

from repro.core.engine import FlashEngine
from repro.core.tiling import largest_pow2_divisor
from repro.models.synthetic_lcsm import SyntheticLCSM

TOL = dict(rtol=2e-4, atol=2e-4)


def _make(strategy, **kw):
    model = SyntheticLCSM(n_levels=3, d_model=8)
    params = model.init(jax.random.PRNGKey(0))
    eng = FlashEngine(model, params, batch=2, strategy=strategy, **kw)
    return model, params, eng


def _run(eng, model, n, prompt=None, origin=0):
    state = eng.init_state()
    if prompt is not None:
        state, _tok = eng.prefill(prompt)
        origin = prompt.shape[1]
    else:
        key = jax.random.PRNGKey(42)
        state = eng.set_first(state, jax.random.normal(key, (2, model.d)))
    state, toks = eng.generate(state, n, origin=origin, rng=jax.random.PRNGKey(7))
    return state


@pytest.mark.parametrize("n_gen", [8, 16, 31])
def test_flash_equals_lazy_and_eager(n_gen):
    _, _, ef = _make("flash", gen_max=n_gen)
    _, _, el = _make("lazy", gen_max=n_gen)
    model, _, ee = _make("eager", gen_max=n_gen)
    sf = _run(ef, model, n_gen)
    sl = _run(el, model, n_gen)
    se = _run(ee, model, n_gen)
    for l in range(len(sf.a)):
        np.testing.assert_allclose(
            sf.a[l][:, :n_gen], sl.a[l][:, :n_gen], **TOL)
        np.testing.assert_allclose(
            sf.a[l][:, :n_gen], se.a[l][:, :n_gen], **TOL)


@pytest.mark.parametrize("tau_impl", ["direct", "fft", "hybrid"])
def test_flash_matches_static_forward(tau_impl):
    n = 16
    model, _, eng = _make("flash", gen_max=n, tau_impl=tau_impl, direct_max=4)
    state = _run(eng, model, n)
    # Replay the a_0 stream through the static train-time path: every level
    # must agree exactly with what the decode loop produced online.
    a0 = state.a[0][:, :n]
    ref = eng.forward_static(a0)
    for l in range(1, len(ref)):
        np.testing.assert_allclose(
            state.a[l][:, :n], ref[l][:, :n], **TOL)


def test_flash_with_prefill_matches_static():
    P, G = 5, 11
    model, _, eng = _make("flash", gen_max=G, prompt_max=P)
    prompt = jax.random.normal(jax.random.PRNGKey(9), (2, P, model.d))
    state = _run(eng, model, G, prompt=prompt)
    n = P + G
    ref = eng.forward_static(state.a[0][:, :n])
    for l in range(1, len(ref)):
        np.testing.assert_allclose(state.a[l][:, :n], ref[l][:, :n], **TOL)


def test_lazy_decode_after_prefill_matches_static():
    """Regression: lazy-strategy decode after a prompt prefill must agree
    with the static forward pass (the lazy fill recomputes each b[l, p]
    from the whole buffered history, prompt included — no origin
    bookkeeping involved)."""
    P, G = 5, 11
    model, _, eng = _make("lazy", gen_max=G, prompt_max=P)
    prompt = jax.random.normal(jax.random.PRNGKey(3), (2, P, model.d))
    state = _run(eng, model, G, prompt=prompt)
    n = P + G
    ref = eng.forward_static(state.a[0][:, :n])
    for l in range(1, len(ref)):
        np.testing.assert_allclose(state.a[l][:, :n], ref[l][:, :n], **TOL)


@pytest.mark.parametrize("P,G", [(3, 12), (1, 9)])
def test_gray_tile_horizon_guard_exact(P, G):
    """Tiles that straddle the buffer horizon (p + U >= Lbuf) must be
    CLIPPED, not dropped: with prompt_max=0 the prompt eats into the
    pow2(gen_max) buffer, so late tiles spill past Lbuf while their
    in-range outputs are still needed.  (The seed dropped the whole tile,
    silently corrupting b near the horizon.)"""
    model = SyntheticLCSM(n_levels=3, d_model=8)
    params = model.init(jax.random.PRNGKey(0))
    eng = FlashEngine(model, params, batch=2, strategy="flash", gen_max=G,
                      prompt_max=0)  # Lbuf = ceil_pow2(G): tight on purpose
    prompt = jax.random.normal(jax.random.PRNGKey(5), (2, P, model.d))
    state, _tok = eng.prefill(prompt)
    n_gen = eng.Lbuf - P - 1   # decode to one position short of the horizon
    assert any(p + largest_pow2_divisor(i) >= eng.Lbuf > p + 1
               for i, p in ((i, P + i - 1) for i in range(1, n_gen))), \
        "test setup must actually hit the partial-tile guard"
    state, _ = eng.generate(state, n_gen, origin=P, rng=jax.random.PRNGKey(7))
    n = P + n_gen
    ref = eng.forward_static(state.a[0][:, :n])
    for l in range(1, len(ref)):
        np.testing.assert_allclose(state.a[l][:, :n], ref[l][:, :n], **TOL)


def test_parallel_levels_matches_sequential():
    n = 16
    model, _, e1 = _make("flash", gen_max=n, parallel_levels=True)
    _, _, e2 = _make("flash", gen_max=n, parallel_levels=False)
    s1 = _run(e1, model, n)
    s2 = _run(e2, model, n)
    for l in range(len(s1.a)):
        np.testing.assert_allclose(s1.a[l], s2.a[l], rtol=1e-6, atol=1e-6)
