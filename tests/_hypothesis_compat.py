"""Offline fallback for ``hypothesis``.

The property tests import ``given``/``settings``/``st`` from here.  When
hypothesis is installed they are the real thing; when it is absent (the
CI container ships no hypothesis) a minimal deterministic shim runs each
property over a seeded example set — boundary values first, then uniform
draws — so the properties are still exercised meaningfully instead of the
whole module failing at collection.

The shim supports exactly what the test-suite uses: ``st.integers``,
``st.floats``, ``st.sampled_from``, ``@settings(max_examples=,
deadline=)``, and positional ``@given(...)``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def boundaries(self):
            return []

        def draw(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = min_value, max_value

        def boundaries(self):
            return [self.lo, self.hi] if self.lo != self.hi else [self.lo]

        def draw(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = float(min_value), float(max_value)

        def boundaries(self):
            return [self.lo, self.hi]

        def draw(self, rng):
            return rng.uniform(self.lo, self.hi)

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def boundaries(self):
            return [self.elements[0], self.elements[-1]]

        def draw(self, rng):
            return rng.choice(self.elements)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, **kw):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

    st = _St()

    def settings(max_examples: int = 20, deadline=None, **kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def run():
                # read off run too so @settings works above OR below @given
                n = getattr(run, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 20))
                # deterministic per-test stream (hash() is salted; crc isn't)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                seen = set()
                cases = []
                # all-lo / all-hi corner cases first, then uniform draws
                for corner in zip(*(s.boundaries() for s in strategies)):
                    if corner not in seen:
                        seen.add(corner)
                        cases.append(corner)
                attempts = 0  # small discrete spaces may have < n cases
                while len(cases) < n and attempts < 50 * n:
                    attempts += 1
                    ex = tuple(s.draw(rng) for s in strategies)
                    if ex not in seen:
                        seen.add(ex)
                        cases.append(ex)
                for ex in cases[:n]:
                    fn(*ex)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco
