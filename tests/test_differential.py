"""Differential test harness: the same decode computed four ways must agree.

Three families of invariants:

* **Strategy-differential** — flash (Alg. 2/3 tiling) vs lazy vs eager vs
  the static train-time forward (``forward_static``) over RANDOMIZED
  configurations (level count, width, dtype, prompt length, decode length)
  drawn through the hypothesis shim — not just the hand-picked cases in
  test_engine.py.  Flash Inference is exact, so any disagreement beyond
  dtype rounding is a bug.

* **GLA ("and Beyond") differential** — the generic §4 engine serving a
  gated-linear-attention LM must agree with BOTH of the mixer's
  independent oracles over randomized dk/dv/λ/decode-length/dtypes: the
  O(L²) ``naive`` evaluation, the O(L) ``recurrent`` RNN mode (token
  streams + activation trajectories), and the fused ``decode_chunk`` path
  must be BIT-identical to the per-step loop — the same contract
  test_decode_chunk.py pins for the Hyena/LCSM engine.

* **Sharding-differential** — a mesh must never change a value: FlashEngine
  under data-axis meshes (1,), (2,), (4,) is BITWISE identical to the
  unsharded engine (every computation is per-slot and τ is
  channel-separable, so a data-sharded decode runs exactly the per-row
  programs a single device would), and LCSMServer(mesh=...) emits bitwise
  identical greedy streams for the same request trace.  These need >= 4
  devices: they run in-process when the suite itself is launched with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI matrix
  leg), and otherwise through a subprocess that forces 4 host devices, so
  the sharded paths are exercised on every run.

Caveat pinned by the batch choices here: slot shards keep >= 2 rows per
device on purpose — XLA CPU lowers single-row matmuls through a gemv path
whose rounding differs from the batched gemm, which would break BITWISE
(not semantic) comparison.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.engine import FlashEngine
from repro.models.synthetic_lcsm import SyntheticLCSM

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# ---------------------------------------------------- strategy differential
_TOL = {"float32": dict(rtol=3e-4, atol=3e-4),
        "bfloat16": dict(rtol=6e-2, atol=6e-2)}


def _decode_state(eng, model, n, P, dtype):
    if P:
        prompt = jax.random.normal(
            jax.random.PRNGKey(9), (eng.batch, P, model.d), jnp.float32)
        state, _ = eng.prefill(prompt.astype(dtype))
    else:
        state = eng.init_state()
        state = eng.set_first(state, jax.random.normal(
            jax.random.PRNGKey(42), (eng.batch, model.d)))
    state, _ = eng.generate(state, n, origin=P, rng=jax.random.PRNGKey(7))
    return state


@given(
    st.integers(min_value=1, max_value=3),        # levels M
    st.sampled_from([4, 8, 16]),                  # width D
    st.integers(min_value=0, max_value=5),        # prompt length P
    st.integers(min_value=6, max_value=18),       # decode length n
    st.sampled_from(["float32", "bfloat16"]),     # activation dtype
)
@settings(max_examples=5, deadline=None)
def test_flash_lazy_eager_static_agree(M, D, P, n, dtype_name):
    """One randomized config, four computations: flash / lazy / eager decode
    plus a forward_static replay of the flash a0 stream — all activation
    stacks must agree to dtype rounding."""
    dtype = jnp.dtype(dtype_name)
    tol = _TOL[dtype_name]
    model = SyntheticLCSM(n_levels=M, d_model=D)
    params = model.init(jax.random.PRNGKey(M * 100 + D))

    states = {}
    for strategy in ("flash", "lazy", "eager"):
        eng = FlashEngine(model, params, batch=2, gen_max=n, prompt_max=P,
                          strategy=strategy, dtype=dtype)
        states[strategy] = (eng, _decode_state(eng, model, n, P, dtype))

    ef, sf = states["flash"]
    T = P + n
    # Cross-strategy runs amplify dtype rounding through the a0 feedback
    # loop (each advance feeds the next step), so bf16 trajectories can
    # diverge chaotically on long horizons — compare a bounded horizon
    # there.  The static replay below has no feedback (it re-runs flash's
    # own a0 stream) and is compared over the full horizon in both dtypes.
    Tc = T if dtype_name == "float32" else P + min(n, 8)
    for other in ("lazy", "eager"):
        _, so = states[other]
        for l in range(len(sf.a)):
            np.testing.assert_allclose(
                np.asarray(sf.a[l][:, :Tc], np.float32),
                np.asarray(so.a[l][:, :Tc], np.float32),
                err_msg=f"flash vs {other}, a[{l}] "
                        f"(M={M} D={D} P={P} n={n} {dtype_name})", **tol)
    ref = ef.forward_static(sf.a[0][:, :T])
    for l in range(1, len(ref)):
        np.testing.assert_allclose(
            np.asarray(sf.a[l][:, :T], np.float32),
            np.asarray(ref[l][:, :T], np.float32),
            err_msg=f"flash vs static, a[{l}] "
                    f"(M={M} D={D} P={P} n={n} {dtype_name})", **tol)


# ------------------------------------------------ GLA ("and Beyond") leg
def _gla_setup(M, D, dk, dv, lam, seed=0, vocab=64):
    from repro.configs import get_config
    from repro.models.gla import GLALM

    cfg = dataclasses.replace(
        get_config("gla").smoke(), name=f"gla-diff-{M}-{dk}-{dv}",
        n_layers=M, d_model=D, d_ff=2 * D, vocab=vocab,
        gla_dk=dk, gla_dv=dv, gla_lam=lam)
    model = GLALM(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(seed))


@given(
    st.integers(min_value=1, max_value=2),        # layers M
    st.sampled_from([(3, 5), (4, 8), (8, 16)]),   # (dk, dv)
    st.floats(min_value=0.7, max_value=0.99),     # decay λ
    st.integers(min_value=6, max_value=14),       # decode length n
    st.sampled_from(["float32", "bfloat16"]),     # engine activation dtype
)
@settings(max_examples=5, deadline=None)
def test_gla_flash_vs_naive_vs_recurrent(M, dkdv, lam, n, dtype_name):
    """One randomized GLA config, three computations: the generic flash
    engine's greedy decode, the recurrent RNN-mode oracle, and the naive
    O(L²) oracle.  Mixer outputs must agree to fp32 tolerance on the flash
    engine's own activation stream, and (f32) the greedy token streams
    must be identical.  bf16 engines are checked against the oracles on
    the re-read mixer level only — the a0 feedback loop amplifies bf16
    rounding chaotically, exactly as in the LCSM differential above."""
    from repro.core.generic import GenericFlashEngine

    dk, dv = dkdv
    D = 16
    cfg, model, params = _gla_setup(M, D, dk, dv, lam)
    dtype = jnp.dtype(dtype_name)
    prompt = np.asarray([3, 7, 11], np.int32)

    eng = GenericFlashEngine(model, params, batch=1, gen_max=16,
                             prompt_max=4, dtype=dtype)
    a0 = model.embed_tokens(params, jnp.asarray(prompt)[None]).astype(dtype)
    state, t0 = eng.prefill(a0)
    state, toks = eng.generate(state, n - 1, origin=len(prompt))
    flash_tokens = [int(t0[0])] + np.asarray(toks)[0].tolist()

    if dtype_name == "float32":
        # greedy streams: flash engine vs the stepwise RNN oracle
        ref = model.decode_recurrent(params, prompt, n)
        assert flash_tokens == ref, (flash_tokens, ref)

    # mixer-level: re-read the engine's own level-0 input stream through
    # both oracles; the engine's per-position states must match them.
    # Finalized positions are 0 .. P+n-2 (the first token comes from the
    # prefill advance at P-1; the last emitted token's own position is
    # never red-passed), so the state comparison stops at T-1.
    T = len(prompt) + n
    ys = state.a[0][:, :T].astype(jnp.float32)
    mix = model.mixers(params)[0]
    z_naive = mix.naive(ys)
    z_rec = mix.recurrent(ys)
    np.testing.assert_allclose(np.asarray(z_naive), np.asarray(z_rec),
                               rtol=2e-4, atol=2e-4,
                               err_msg=f"naive vs recurrent (λ={lam})")
    z_eng = jax.vmap(mix.read, in_axes=1, out_axes=1)(
        state.s[0][:, : T - 1], ys[:, : T - 1])
    np.testing.assert_allclose(np.asarray(z_eng), np.asarray(z_rec[:, : T - 1]),
                               rtol=2e-4, atol=2e-4,
                               err_msg=f"engine states vs recurrent "
                                       f"(M={M} dk={dk} dv={dv} λ={lam:.3f} "
                                       f"n={n} {dtype_name})")


@given(
    st.sampled_from([2, 3, 4, 8]),               # chunk K
    st.integers(min_value=0, max_value=4),       # prompt length P
    st.sampled_from(["float32", "bfloat16"]),    # dtype
)
@settings(max_examples=6, deadline=None)
def test_gla_decode_chunk_bit_identical_to_stepwise(K, P, dtype_name):
    """The generic engine's fused decode_chunk must reproduce the per-step
    loop BITWISE — tokens and every a/s buffer — across chunk sizes,
    prompt origins, and dtypes (the mixer's mul+reduce contractions keep
    XLA CPU's codegen fusion-invariant; see GatedLinearAttention)."""
    from repro.core.generic import GenericFlashEngine

    cfg, model, params = _gla_setup(2, 16, 4, 8, 0.93)
    dtype = jnp.dtype(dtype_name)
    n = 14
    prompt = np.asarray([5, 2, 9, 13], np.int32)[:max(P, 1)]

    def run(chunk_size):
        eng = GenericFlashEngine(model, params, batch=2, gen_max=16,
                                 prompt_max=4, dtype=dtype,
                                 chunk_size=chunk_size)
        if P:
            a0 = model.embed_tokens(
                params, jnp.tile(jnp.asarray(prompt)[None], (2, 1)))
            state, t0 = eng.prefill(a0.astype(dtype))
            state, toks = eng.generate(state, n, origin=len(prompt))
        else:
            state = eng.set_first(
                eng.init_state(),
                model.embed_tokens(params, jnp.zeros((2, 1), jnp.int32))[:, 0])
            state, toks = eng.generate(state, n, origin=0)
        return state, np.asarray(toks)

    s1, t1 = run(1)
    sK, tK = run(K)
    np.testing.assert_array_equal(t1, tK)
    for l in range(len(s1.a)):
        np.testing.assert_array_equal(
            np.asarray(s1.a[l]), np.asarray(sK.a[l]),
            err_msg=f"a[{l}] K={K} P={P} {dtype_name}")
    for l in range(len(s1.s)):
        np.testing.assert_array_equal(
            np.asarray(s1.s[l]), np.asarray(sK.s[l]),
            err_msg=f"s[{l}] K={K} P={P} {dtype_name}")


# ---------------------------------------------------- sharding differential
def _mesh(data, model=1):
    from repro.launch.mesh import make_serving_mesh
    return make_serving_mesh(data=data, model=model)


def _engine_run(mesh, chunk_size=1, batch=8, n=16):
    model = SyntheticLCSM(n_levels=2, d_model=8)
    params = model.init(jax.random.PRNGKey(0))
    eng = FlashEngine(model, params, batch=batch, gen_max=n,
                      chunk_size=chunk_size, mesh=mesh)
    state = eng.init_state()
    state = eng.set_first(state, jax.random.normal(
        jax.random.PRNGKey(42), (batch, model.d)))
    state, _ = eng.generate(state, n, rng=jax.random.PRNGKey(7))
    return state


needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4); covered "
           "by test_sharded_bit_identity_subprocess otherwise")


@needs4
@pytest.mark.parametrize("shape", [(1, 1), (2, 1), (4, 1), (2, 2)])
@pytest.mark.parametrize("chunk", [1, 4])
def test_sharded_engine_bitwise_identical(shape, chunk):
    """Mesh shapes (1,), (2,), (4,) on the data axis — and one (2, 2)
    data×model mesh — must reproduce the unsharded decode BITWISE, both
    per-step and through the fused chunk path."""
    ref = _engine_run(None, chunk_size=chunk)
    got = _engine_run(_mesh(*shape), chunk_size=chunk)
    for l in range(len(ref.a)):
        np.testing.assert_array_equal(
            np.asarray(ref.a[l]), np.asarray(got.a[l]),
            err_msg=f"a[{l}] mesh={shape} chunk={chunk}")
    for l in range(len(ref.b)):
        np.testing.assert_array_equal(
            np.asarray(ref.b[l]), np.asarray(got.b[l]),
            err_msg=f"b[{l}] mesh={shape} chunk={chunk}")


@needs4
@pytest.mark.parametrize("chunk", [None, 4])
def test_sharded_server_streams_bit_identical(chunk):
    """LCSMServer(mesh=(4,) data) over a mixed continuous-batching trace:
    every greedy stream must equal the single-device server's, token for
    token, per-step and chunked."""
    from repro.configs import get_config
    from repro.models.hyena import HyenaLCSM
    from repro.serving import Request, make_server

    cfg = dataclasses.replace(get_config("hyena").smoke(), name="hyena-shard",
                              n_layers=2, d_model=16, d_ff=32, vocab=64)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    pmax, gmax = 4, 8

    def run(mesh):
        srv = make_server(cfg, params, n_slots=8, prompt_max=pmax,
                          gen_max=gmax, mesh=mesh)
        rng = np.random.RandomState(0)
        reqs = [Request(uid=i,
                        prompt=rng.randint(0, cfg.vocab, (
                            int(rng.randint(1, pmax + 1)),)).astype(np.int32),
                        max_new=int(rng.randint(2, gmax + 1)))
                for i in range(10)]
        for r in reqs:
            srv.submit(r)
        srv.run(chunk=chunk)
        return {r.uid: tuple(r.out) for r in reqs}

    assert run(_mesh(4)) == run(None)


@needs4
def test_sharded_transformer_server_streams_identical():
    """ServingEngine(mesh=...) — the transformer-family backend shares the
    mesh contract (slots→data via launch/sharding.cache_specs): greedy
    streams over a mixed trace must equal the single-device server's."""
    from repro.configs import get_config
    from repro.models.lm import LM
    from repro.serving import Request, make_server

    cfg = get_config("qwen2.5-3b").smoke()
    params = LM(cfg).init(jax.random.PRNGKey(0))

    def run(mesh):
        srv = make_server(cfg, params, n_slots=4, max_seq=16,
                          cache_dtype=jnp.float32, mesh=mesh)
        rng = np.random.RandomState(1)
        reqs = [Request(uid=i,
                        prompt=rng.randint(0, cfg.vocab, (
                            int(rng.randint(1, 5)),)).astype(np.int32),
                        max_new=int(rng.randint(2, 7)))
                for i in range(6)]
        for r in reqs:
            srv.submit(r)
        srv.run()
        return {r.uid: tuple(r.out) for r in reqs}

    assert run(_mesh(2)) == run(None)


_SUBPROC_SCRIPT = """
import numpy as np, jax
from repro.core.engine import FlashEngine
from repro.models.synthetic_lcsm import SyntheticLCSM
from repro.launch.mesh import make_serving_mesh

assert jax.device_count() >= 4, jax.device_count()
model = SyntheticLCSM(n_levels=2, d_model=8)
params = model.init(jax.random.PRNGKey(0))

def run(mesh):
    eng = FlashEngine(model, params, batch=8, gen_max=8, mesh=mesh)
    state = eng.init_state()
    state = eng.set_first(state, jax.random.normal(jax.random.PRNGKey(42), (8, model.d)))
    state, _ = eng.generate(state, 8, rng=jax.random.PRNGKey(7))
    return state

ref = run(None)
for n in (1, 2, 4):
    got = run(make_serving_mesh(data=n))
    for l in range(len(ref.a)):
        np.testing.assert_array_equal(np.asarray(ref.a[l]), np.asarray(got.a[l]))
print("SHARDED-BIT-IDENTITY-OK")
"""


def test_sharded_bit_identity_subprocess():
    """Always-on sharded coverage: when this pytest process has a single
    device (the default CI leg), spawn a subprocess with 4 forced host
    devices and assert mesh (1,), (2,), (4,) decode is bitwise identical to
    unsharded there."""
    if jax.device_count() >= 4:
        pytest.skip("in-process sharded differential tests already ran")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), env.get("PYTHONPATH", "")]).rstrip(
            os.pathsep)
    out = subprocess.run([sys.executable, "-c", _SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARDED-BIT-IDENTITY-OK" in out.stdout
