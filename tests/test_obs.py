"""Flashtrace (repro.obs): the observability subsystem's contracts.

The one that matters most is BITWISE NON-INTERFERENCE: serving the same
trace with tracing enabled must emit exactly the token streams the
untraced run emits — LCSM and GLA, per-step and chunked, replicas and
mesh (device-gated).  Flashtrace lives entirely on the host side of the
dispatch boundary (flashcheck FC007 + the jaxpr trace-invariance entry
enforce the same contract statically), so this suite pins the runtime
half: instrumentation changes WHEN the host looks at the clock, never
WHAT the device computes.

Plus the mechanics: ring-buffer wrap accounting, Perfetto export schema
(well-nested spans per track, JSON round-trip), Prometheus text shape,
disabled-path overhead, and the ServingMetrics first->last event-span
throughput fix (idle time before traffic must not deflate tok/s).
"""

import dataclasses
import json
import time

import jax
import pytest

from repro import obs
from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.obs import trace as obs_trace
from repro.serving import make_server
from repro.serving.frontend import (PrefixCache, ServingMetrics,
                                    TrafficScheduler, make_frontend,
                                    poisson_trace)

PROMPT_MAX, GEN_MAX = 8, 16


@pytest.fixture(autouse=True)
def _no_leaked_recorder():
    """Every test leaves tracing OFF — a leaked recorder would silently
    turn every later test into a tracing-on run."""
    yield
    obs.disable_tracing()
    assert obs_trace.RECORDER is None


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("hyena").smoke(), name="hyena-obs",
                              n_layers=4, d_model=32, d_ff=64, vocab=128)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def gla_setup():
    from repro.models.gla import GLALM

    cfg = dataclasses.replace(get_config("gla").smoke(), name="gla-obs",
                              n_layers=2, d_model=32, d_ff=64, vocab=128,
                              gla_dk=8, gla_dv=32)
    params = GLALM(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _serve_streams(cfg, params, *, chunk, traced: bool, **server_kw):
    """One full frontend serve of a fixed trace; returns {uid: stream}."""
    if traced:
        obs.enable_tracing()
    try:
        srv = make_server(cfg, params, n_slots=2, prompt_max=PROMPT_MAX,
                          gen_max=GEN_MAX, **server_kw)
        sched = make_frontend(srv, prefix_cache=True, chunk=chunk)
        trace = poisson_trace(cfg.vocab, 7, rate=0.7, prompt_max=PROMPT_MAX,
                              gen_max=10, hit_frac=0.6, seed=3)
        for _ in sched.serve(trace):
            pass
        return {tr.req.uid: tuple(tr.req.out) for tr in trace}
    finally:
        obs.disable_tracing()


# ----------------------------------------------------- bitwise non-interference
@pytest.mark.parametrize("family,chunk", [
    ("lcsm", None), ("lcsm", 4), ("gla", None), ("gla", 4)])
def test_streams_bitwise_identical_tracing_on_vs_off(setup, gla_setup,
                                                     family, chunk):
    cfg, params = setup if family == "lcsm" else gla_setup
    off = _serve_streams(cfg, params, chunk=chunk, traced=False)
    on = _serve_streams(cfg, params, chunk=chunk, traced=True)
    assert on == off
    assert any(len(s) for s in off.values())


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="replica parity needs >= 2 devices")
def test_streams_bitwise_identical_tracing_on_vs_off_replicas(setup):
    cfg, params = setup
    off = _serve_streams(cfg, params, chunk=4, traced=False, replicas=2)
    on = _serve_streams(cfg, params, chunk=4, traced=True, replicas=2)
    assert on == off


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="mesh parity needs >= 2 devices")
def test_streams_bitwise_identical_tracing_on_vs_off_mesh(setup):
    from repro.launch.mesh import make_serving_mesh

    cfg, params = setup
    mesh = make_serving_mesh(data=2)
    off = _serve_streams(cfg, params, chunk=4, traced=False, mesh=mesh)
    on = _serve_streams(cfg, params, chunk=4, traced=True, mesh=mesh)
    assert on == off


def test_tracing_does_not_trigger_recompiles(setup):
    """Enabling tracing mid-flight must not grow the engine's jit caches:
    the cached chunk programs are reused untouched (the compiled-program
    half of the non-interference contract)."""
    cfg, params = setup
    srv = make_server(cfg, params, n_slots=2, prompt_max=PROMPT_MAX,
                      gen_max=GEN_MAX)
    sched = make_frontend(srv, chunk=4)
    trace = poisson_trace(cfg.vocab, 4, rate=0.7, prompt_max=PROMPT_MAX,
                          gen_max=8, seed=3)
    for _ in sched.serve(trace):
        pass
    sizes = (len(srv.engine._jit_server_chunk), len(srv.engine._jit_gray))
    obs.enable_tracing()
    try:
        # identical workload replayed traced: every program is a cache hit
        sched2 = make_frontend(srv, chunk=4)
        trace2 = poisson_trace(cfg.vocab, 4, rate=0.7, prompt_max=PROMPT_MAX,
                               gen_max=8, seed=3)
        for _ in sched2.serve(trace2):
            pass
    finally:
        obs.disable_tracing()
    assert (len(srv.engine._jit_server_chunk),
            len(srv.engine._jit_gray)) == sizes


# ------------------------------------------------------------- span recorder
def test_ring_buffer_wrap_accounting():
    rec = obs_trace.SpanRecorder(capacity=4)
    for i in range(7):
        rec.add_span(f"s{i}", "t", float(i), float(i) + 0.5)
    spans = rec.spans_view()
    assert [s[0] for s in spans] == ["s3", "s4", "s5", "s6"]  # oldest-first
    assert rec.dropped["spans"] == 3
    assert rec.dropped["instants"] == 0


def test_counters_and_gauges_flatten_with_sorted_labels():
    rec = obs_trace.SpanRecorder()
    rec.inc_counter("c", 2, b="y", a="x")
    rec.inc_counter("c", 3, a="x", b="y")  # same labels, any kwarg order
    rec.set_gauge("g", 7.5, tier="device")
    assert rec.counters_view() == {'c{a="x",b="y"}': 5.0}
    assert rec.gauges_view() == {'g{tier="device"}': 7.5}


def test_disabled_path_overhead_smoke(setup):
    """The off path of an instrumented host wrapper is one module-attr
    load + None test.  Generous bound (CI machines are noisy): the pure
    guard must stay under 2 µs/op."""
    n = 200_000
    t0 = time.perf_counter()
    acc = 0
    for _ in range(n):
        if obs_trace.RECORDER is not None:  # the exact guard the wrappers use
            acc += 1
    per_op = (time.perf_counter() - t0) / n
    assert acc == 0
    assert per_op < 2e-6, f"{per_op * 1e9:.0f} ns/op"


# ---------------------------------------------------------------- exporters
def _traced_run(setup):
    cfg, params = setup
    rec = obs.enable_tracing()
    try:
        _serve_streams(cfg, params, chunk=4, traced=False)  # rec already on
        return rec
    finally:
        obs_trace.RECORDER = None  # keep rec's data readable after the run


def test_perfetto_export_schema(setup, tmp_path):
    rec = _traced_run(setup)
    path = tmp_path / "trace.json"
    obs.write_trace_json(rec, str(path))
    doc = json.loads(path.read_text())  # JSON round-trip
    evs = doc["traceEvents"]
    names = {e["name"] for e in evs}
    assert {"server.dispatch_chunk", "server.collect_chunk",
            "engine.server_chunk", "frontend.queue_wait"} <= names
    # one pid; every span/instant lands on a declared named track
    tid2track = {e["tid"]: e["args"]["name"] for e in evs
                 if e["name"] == "thread_name"}
    spans = [e for e in evs if e.get("ph") == "X"]
    assert spans and all(e["tid"] in tid2track for e in spans)
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in spans)
    # Spans on the call-stack-shaped tracks are well-nested: each span
    # either starts after the previous ends or lies fully inside it.
    # (frontend queue_wait spans measure per-request waits, which overlap
    # legitimately — they are excluded from the nesting claim.)
    for tid, track in tid2track.items():
        if track not in ("engine", "server"):
            continue
        stack = []
        for e in sorted((e for e in spans if e["tid"] == tid),
                        key=lambda e: (e["ts"], -e["dur"])):
            while stack and e["ts"] >= stack[-1]:
                stack.pop()
            end = e["ts"] + e["dur"]
            assert not stack or end <= stack[-1] + 1e-3, \
                f"overlapping spans on track {track}"
            stack.append(end)


def test_prometheus_export_shape(setup):
    rec = _traced_run(setup)
    text = obs.prometheus_text(rec)
    lines = [ln for ln in text.splitlines() if ln]
    typed = {ln.split()[2]: ln.split()[3]
             for ln in lines if ln.startswith("# TYPE")}
    assert typed.get("flash_dispatch_total") == "counter"
    assert typed.get("flash_jit_cache_size") == "gauge"
    assert typed.get("flashtrace_dropped_events") == "counter"
    for ln in lines:
        if ln.startswith("#"):
            continue
        name, _, value = ln.partition(" ")
        float(value)  # every sample line parses
        base = name.partition("{")[0]
        assert base in typed, f"untyped metric {name}"
    # the counters that make the trace story: program-cache hits vs misses
    assert any("flash_program_cache_total" in ln and 'event="miss"' in ln
               for ln in lines)
    assert any("prefix_cache_lookups_total" in ln for ln in lines)


def test_metrics_snapshot_carries_obs_rollup(setup):
    cfg, params = setup
    obs.enable_tracing()
    try:
        srv = make_server(cfg, params, n_slots=2, prompt_max=PROMPT_MAX,
                          gen_max=GEN_MAX)
        sched = TrafficScheduler(srv, chunk=4, prefix_cache=PrefixCache())
        trace = poisson_trace(cfg.vocab, 5, rate=0.7, prompt_max=PROMPT_MAX,
                              gen_max=8, seed=3)
        rep = sched.run(trace)
    finally:
        obs.disable_tracing()
    rollup = rep.metrics["obs"]
    assert set(rollup) == {"counters", "gauges", "dropped"}
    assert any(k.startswith("flash_dispatch_total") for k in
               rollup["counters"])
    # ...and stays OUT of the snapshot when tracing is off
    m2 = ServingMetrics()
    assert "obs" not in m2.snapshot()


# --------------------------------------------------- ServingMetrics tok/s fix
def test_tok_s_measured_over_event_span_not_object_lifetime():
    """Idle wall time before the first event (or after the last) must not
    deflate throughput: tok/s is tokens / (last event - first event)."""
    fake = {"t": 100.0}
    m = ServingMetrics(clock=lambda: fake["t"])
    fake["t"] = 500.0            # long idle gap after construction
    m.on_submit(0, step=0)
    fake["t"] = 501.0
    m.on_admit(0, step=1, cache_hit=False)
    m.on_tokens(0, 10, step=1)
    fake["t"] = 502.0
    m.on_tokens(0, 10, step=2)
    m.on_finish(0, step=2)
    fake["t"] = 900.0            # snapshot() long after traffic ended
    snap = m.snapshot()
    assert snap["throughput"]["wall_s"] == pytest.approx(2.0)
    assert snap["throughput"]["tok_s"] == pytest.approx(10.0)


def test_tok_s_zero_before_any_event():
    m = ServingMetrics(clock=lambda: 42.0)
    snap = m.snapshot()
    assert snap["throughput"]["wall_s"] == 0.0
    assert snap["throughput"]["tok_s"] == 0.0
