"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per instructions: sweep shapes/dtypes, assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {
    jnp.float32: dict(rtol=1e-5, atol=1e-5),
    jnp.bfloat16: dict(rtol=2e-2, atol=2e-2),
}


# -------------------------------------------------------------- tile_conv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("U", [1, 2, 4, 8, 16, 64])
@pytest.mark.parametrize("C", [1, 7, 128, 200])
def test_tile_conv_shapes_dtypes(U, C, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(U * 1000 + C))
    y = _rand(k1, (2, U, C), dtype)
    rho = _rand(k2, (2 * U, C), jnp.float32)
    got = ops.tile_conv(y, rho)
    want = ref.tile_conv_ref(y, rho)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype])


def test_tile_conv_group_batch_broadcast():
    G, B, U, C = 3, 2, 8, 5
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    y = _rand(k1, (G, B, U, C), jnp.float32)
    rho = _rand(k2, (G, 1, 2 * U, C), jnp.float32)
    got = ops.tile_conv(y, rho)
    want = ref.tile_conv_ref(y, rho)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    st.sampled_from([1, 2, 4, 8, 32]),
    st.integers(min_value=1, max_value=130),
    st.integers(min_value=1, max_value=3),
)
@settings(max_examples=12, deadline=None)
def test_tile_conv_property(U, C, B):
    k1, k2 = jax.random.split(jax.random.PRNGKey(U + C * 31 + B))
    y = _rand(k1, (B, U, C), jnp.float32)
    rho = _rand(k2, (2 * U, C), jnp.float32)
    np.testing.assert_allclose(
        ops.tile_conv(y, rho), ref.tile_conv_ref(y, rho), rtol=1e-5, atol=1e-5)


def test_tile_conv_matches_tau_direct():
    from repro.core import tau as tau_mod
    U, C = 16, 64
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    y = _rand(k1, (4, U, C), jnp.float32)
    rho = _rand(k2, (2 * U, C), jnp.float32)
    np.testing.assert_allclose(
        ops.tile_conv(y, rho), tau_mod.tau_direct(y, rho), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- short_conv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,K,block_t", [(4, 4, 128), (17, 3, 8), (128, 4, 32),
                                         (300, 4, 128)])
@pytest.mark.parametrize("C", [3, 128, 150])
def test_short_conv_shapes_dtypes(T, K, block_t, C, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(T * 7 + K + C), 3)
    x = _rand(k1, (2, T, C), dtype)
    w = _rand(k2, (K, C), jnp.float32)
    b = _rand(k3, (C,), jnp.float32)
    got = ops.short_conv(x, w, b, block_t=block_t)
    want = ref.short_conv_ref(x, w, b)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype])


def test_short_conv_no_bias_causality():
    # Impulse response: output must not see the future.
    T, C, K = 32, 128, 4
    w = jnp.ones((K, C), jnp.float32)
    x = jnp.zeros((1, T, C)).at[0, 10].set(1.0)
    y = np.asarray(ops.short_conv(x, w))
    assert np.all(y[0, :10] == 0)           # nothing before the impulse
    assert np.all(y[0, 10:14] == 1.0)       # K taps after it
    assert np.all(y[0, 14:] == 0)


@given(st.integers(min_value=1, max_value=64), st.integers(min_value=1, max_value=4))
@settings(max_examples=10, deadline=None)
def test_short_conv_property(T, K):
    k1, k2 = jax.random.split(jax.random.PRNGKey(T * 5 + K))
    x = _rand(k1, (1, T, 16), jnp.float32)
    w = _rand(k2, (K, 16), jnp.float32)
    np.testing.assert_allclose(
        ops.short_conv(x, w), ref.short_conv_ref(x, w), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- decode_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,chunk", [(8, 8), (100, 32), (257, 64), (1024, 256)])
@pytest.mark.parametrize("K,G,hd", [(1, 1, 8), (2, 4, 16), (8, 2, 128)])
def test_decode_attention_shapes_dtypes(S, chunk, K, G, hd, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(S + K * 7 + hd), 4)
    q = _rand(ks[0], (B, K, G, hd), dtype)
    k = _rand(ks[1], (B, S, K, hd), dtype)
    v = _rand(ks[2], (B, S, K, hd), dtype)
    pos = jax.random.randint(ks[3], (B,), 1, S + 1)
    got = ops.decode_attention(q, k, v, pos, chunk=chunk)
    want = ref.decode_attention_ref(q, k, v, pos)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


def test_decode_attention_respects_validity():
    """Entries at positions >= pos must not influence the output."""
    B, K, G, hd, S = 1, 1, 2, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (B, K, G, hd), jnp.float32)
    k = _rand(ks[1], (B, S, K, hd), jnp.float32)
    v = _rand(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.asarray([17])
    base = ops.decode_attention(q, k, v, pos, chunk=16)
    # poison the invalid tail
    k2 = k.at[:, 17:].set(1e3)
    v2 = v.at[:, 17:].set(-1e3)
    poisoned = ops.decode_attention(q, k2, v2, pos, chunk=16)
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


@given(st.integers(min_value=1, max_value=96), st.sampled_from([8, 32]))
@settings(max_examples=8, deadline=None)
def test_decode_attention_property(pos_v, chunk):
    B, K, G, hd, S = 1, 2, 2, 8, 96
    ks = jax.random.split(jax.random.PRNGKey(pos_v), 3)
    q = _rand(ks[0], (B, K, G, hd), jnp.float32)
    k = _rand(ks[1], (B, S, K, hd), jnp.float32)
    v = _rand(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.asarray([pos_v])
    np.testing.assert_allclose(
        ops.decode_attention(q, k, v, pos, chunk=chunk),
        ref.decode_attention_ref(q, k, v, pos), rtol=2e-5, atol=2e-5)


# ------------------------------------------------- fused gray tile / red cell
# The fused kernels promise BITWISE equality (interpret mode) against the
# engines' XLA reference bodies — not allclose.  The reference bodies are
# pinned (engine._gray_tile / generic._apply_tile), so these tests build
# real engines and diff whole state planes.
from repro.core import tau as tau_mod
from repro.core.engine import FlashEngine, LevelSpec, _slice_rows
from repro.core.generic import LongConvMixer, _apply_tile
from repro.core.schedule import slice_rows
from repro.kernels.heuristic import FUSED_MAX_U, MIN_PROGRAMS, gray_plan
from repro.models import components as mcomp


class _MixedLCSM:
    """Two conv-width groups (3 and 5) with nonzero conv_starts — exercises
    per-group batching, channel offsets, and multi-level scatter in one
    model.  Blocks are plain MLPs; advance is deterministic."""

    ctx_window = 0

    def __init__(self):
        self.a0_width = 8
        self.levels = (
            LevelSpec(width=8, conv_start=2, conv_size=3),
            LevelSpec(width=8, conv_start=0, conv_size=5),
            LevelSpec(width=8, conv_start=1, conv_size=3),
            LevelSpec(width=8, conv_start=3, conv_size=5),
        )
        self.M = 4

    def init(self, key):
        ks = jax.random.split(key, self.M + 1)
        return {"filter_key": jax.random.key_data(ks[0]),
                "blocks": [mcomp.init_mlp_gelu(ks[1 + l], 8, 16)
                           for l in range(self.M)]}

    def filters(self, params, length):
        key = jax.random.wrap_key_data(params["filter_key"])
        return [jax.random.normal(jax.random.fold_in(key, l),
                                  (length, s.conv_size), jnp.float32)
                for l, s in enumerate(self.levels)]

    def block(self, params, level, b, acts):
        pad = self.levels[level].width - b.shape[-1]
        return jnp.pad(b, ((0, 0), (0, 0), (0, pad)))

    def advance(self, params, acts, rng):
        top = acts[self.M][:, -1]
        return jnp.tanh(top), jnp.zeros((top.shape[0],), jnp.int32)


def _gray_engines(B=8, gen_max=32, **kw):
    model = _MixedLCSM()
    params = model.init(jax.random.PRNGKey(1))
    return {impl: FlashEngine(model, params, batch=B, gen_max=gen_max,
                              gray_impl=impl, **kw)
            for impl in ("xla", "pallas")}


def _random_gray_state(eng, key, straddle=False):
    st = eng.init_state()
    ks = jax.random.split(key, 2 * len(st.a))
    a = tuple(jax.random.normal(ks[i], x.shape, x.dtype)
              for i, x in enumerate(st.a))
    b = tuple(jax.random.normal(ks[len(st.a) + i], x.shape, jnp.float32)
              for i, x in enumerate(st.b))
    if straddle:
        # sprinkle -0.0 so the scatter's +0.0 sign semantics are exercised
        b = tuple(jnp.where(jax.random.bernoulli(ks[i], 0.25, x.shape),
                            -0.0, x)
                  for i, x in enumerate(b))
    return st._replace(a=a, b=b)


@pytest.mark.parametrize("U", [2, 4, 8, 16])
@pytest.mark.parametrize("parallel_levels", [True, False])
def test_gray_fused_bitwise_vs_xla_reference(U, parallel_levels):
    """Interpret-mode fused gray tile == the XLA gather/τ/scatter body,
    bit for bit, on a multi-group model with random masks."""
    engs = _gray_engines(parallel_levels=parallel_levels)
    e_ref, e_fused = engs["xla"], engs["pallas"]
    plan = e_fused._gray_plan(U, 3, [8, 8])
    assert plan is not None and plan.fused, plan
    for trial in range(3):
        key = jax.random.PRNGKey(1000 * U + trial)
        st = _random_gray_state(e_ref, key)
        p = jax.random.randint(jax.random.fold_in(key, 2), (e_ref.batch,),
                               U - 1, e_ref.Lbuf, dtype=jnp.int32)
        mask = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.5,
                                    (e_ref.batch,))
        want = jax.jit(lambda s, pp, mm: e_ref._gray_tile(
            None, s, pp, mm, U=U))(st, p, mask)
        got = jax.jit(lambda s, pp, mm: e_fused._gray_tile(
            None, s, pp, mm, U=U))(st, p, mask)
        for l in range(len(want.b)):
            np.testing.assert_array_equal(
                np.asarray(want.b[l]), np.asarray(got.b[l]),
                err_msg=f"U={U} trial={trial} level={l}")


@pytest.mark.parametrize("U", [2, 8])
def test_gray_fused_bitwise_on_horizon_straddle(U):
    """Tiles whose output window spills past Lbuf clip exactly like the
    reference scatter (including the +0.0 writes that flip stored -0.0)."""
    engs = _gray_engines()
    e_ref, e_fused = engs["xla"], engs["pallas"]
    Lbuf = e_ref.Lbuf
    key = jax.random.PRNGKey(77 + U)
    st = _random_gray_state(e_ref, key, straddle=True)
    # every slot near (or at) the horizon so windows straddle/spill fully
    p = jnp.asarray([Lbuf - 1, Lbuf - 2, Lbuf - U, Lbuf - U - 1,
                     max(U - 1, Lbuf - 2 * U), Lbuf - 1, U - 1, Lbuf - 3],
                    jnp.int32)[: e_ref.batch]
    mask = jnp.asarray([True, True, False, True, True, False, True, True],
                       bool)[: e_ref.batch]
    want = jax.jit(lambda s: e_ref._gray_tile(None, s, p, mask, U=U))(st)
    got = jax.jit(lambda s: e_fused._gray_tile(None, s, p, mask, U=U))(st)
    for l in range(len(want.b)):
        np.testing.assert_array_equal(
            np.asarray(want.b[l]), np.asarray(got.b[l]),
            err_msg=f"straddle U={U} level={l}")


def test_gray_plan_gating():
    """The dispatch heuristic keeps the XLA body outside the fused regime
    and sizes slot_block from the VMEM budget."""
    common = dict(C=8, batch=8, widths=[8, 8], Lbuf=64)
    assert gray_plan(U=8, **common).fused
    # U=1 floor (lcsm engines pass min_u=2: bare-multiply FMA hazard)
    p1 = gray_plan(U=1, min_u=2, **common)
    assert not p1.fused and "floor" in p1.reason
    # FFT regime
    pf = gray_plan(U=64, direct_max=32, **common)
    assert not pf.fused and "direct regime" in pf.reason
    assert not gray_plan(U=max(2, FUSED_MAX_U * 2), **common).fused
    # non-pow2 and beyond-horizon tiles
    assert not gray_plan(U=6, **common).fused
    assert not gray_plan(U=8, C=8, batch=8, widths=[8], Lbuf=4).fused
    # slot_block: power of two dividing batch, grid >= MIN_PROGRAMS
    pl = gray_plan(U=8, **common)
    assert pl.slot_block & (pl.slot_block - 1) == 0
    assert common["batch"] % pl.slot_block == 0
    assert common["batch"] // pl.slot_block >= MIN_PROGRAMS
    # a tiny VMEM budget forces slot_block=1, then rejects fusion outright
    tiny = gray_plan(U=8, vmem_budget=1, **common)
    assert not tiny.fused and "VMEM" in tiny.reason


def test_engine_gray_plan_respects_tau_impl():
    """Only direct-regime dispatches of the plain τ impls may fuse: the
    tile_conv and FFT bodies round differently than tau_direct."""
    engs = _gray_engines(tau_impl="fft")
    assert engs["pallas"]._gray_plan(4, 3, [8, 8]) is None
    engs = _gray_engines(use_pallas=True)
    assert engs["pallas"]._gray_plan(4, 3, [8, 8]) is None
    engs = _gray_engines(direct_max=4)
    plan = engs["pallas"]._gray_plan(8, 3, [8, 8])
    assert plan is not None and not plan.fused
    assert engs["xla"]._gray_plan(4, 3, [8, 8]) is None


def test_red_pass_fma_bitwise():
    """Fused red cell == the two dynamic slices + mul-add chain, bitwise
    (both sides present the same mul+add pattern to the compiler, so any
    FMA contraction applies to both)."""
    B, Lbuf, W, C, cs = 4, 16, 8, 5, 2
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    a = jax.random.normal(ks[0], (B, Lbuf, W), jnp.float32)
    b = jax.random.normal(ks[1], (B, Lbuf, C), jnp.float32)
    rho0 = jax.random.normal(ks[2], (C,), jnp.float32)
    p = jnp.asarray([0, 5, Lbuf - 1, 7], jnp.int32)

    def ref_red(a, b, p):
        y_p = _slice_rows(a, p, cs, 1, C)
        b_p = _slice_rows(b, p, 0, 1, C)
        return b_p + y_p.astype(jnp.float32) * rho0

    want = jax.jit(ref_red)(a, b, p)
    got = jax.jit(lambda a, b, p: ops.red_pass_fma(
        a, b, rho0, p, conv_start=cs))(a, b, p)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("U", [1, 2, 4, 8])
@pytest.mark.parametrize("slot_block", [1, 2])
def test_gray_select_mode_bitwise_vs_apply_tile(U, slot_block):
    """Select-mode fused kernel == the generic engine's range_alg +
    _apply_tile composition (clamped window, select merge — U=1 included:
    the gather between τ and agg blocks FMA contraction symmetrically)."""
    B, Lbuf, C = 4, 32, 6
    key = jax.random.PRNGKey(10 * U + slot_block)
    ks = jax.random.split(key, 5)
    rho = jax.random.normal(ks[0], (Lbuf, C), jnp.float32)
    mix = LongConvMixer(rho)
    a = jax.random.normal(ks[1], (B, Lbuf, C), jnp.float32)
    s = jax.random.normal(ks[2], (B, Lbuf, C), jnp.float32)
    p = jax.random.randint(ks[3], (B,), U - 1, Lbuf, dtype=jnp.int32)
    mask = jax.random.bernoulli(ks[4], 0.5, (B,))

    def ref(a, s, p, mask):
        start = p - U + 1
        y_seg = slice_rows(a, start, 0, U, C)
        contrib = mix.range_alg(y_seg, start, jnp.arange(1, U + 1))
        return _apply_tile(mix, s, p, contrib, mask, U, Lbuf)

    want = jax.jit(ref)(a, s, p, mask)
    got = jax.jit(lambda a, s, p, mask: ops.gray_tile_apply(
        [a], [s], mix.tile_filter(U)[None], p, mask, conv_starts=[0],
        Lbuf=Lbuf, mode="select", slot_block=slot_block)[0])(a, s, p, mask)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_interpret_override_hook():
    """kernels.ops resolves interpret-vs-compile from the backend once and
    caches it; the override hook forces either mode explicitly."""
    base = ops.interpret_default()
    prev = ops.set_interpret_override(not base)
    try:
        assert ops.interpret_default() is (not base)
    finally:
        ops.set_interpret_override(prev)
    assert ops.interpret_default() is base


def test_tile_conv_shared_filter_not_materialized():
    """A filter with no leading dims must enter the kernel as ONE shared
    block — not one broadcast copy per grid program (the old body
    materialized (nb, 2U, C))."""
    nb, U, C = 8, 4, 128
    y = jnp.zeros((nb, U, C), jnp.float32)
    rho = jnp.zeros((2 * U, C), jnp.float32)
    jaxpr = str(jax.make_jaxpr(lambda y, r: ops.tile_conv(y, r))(y, rho))
    assert f"f32[{nb},{2 * U},{C}]" not in jaxpr, \
        "per-program filter copies are back"
    # result is unchanged vs the oracle
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    y = jax.random.normal(k1, (nb, U, C), jnp.float32)
    rho = jax.random.normal(k2, (2 * U, C), jnp.float32)
    np.testing.assert_allclose(ops.tile_conv(y, rho),
                               ref.tile_conv_ref(y, rho),
                               rtol=1e-5, atol=1e-5)


class _LongConvModel:
    """Minimal GenericModel over LongConvMixer levels (generic-framework
    LCSM): block = tanh(z) + y keeps every level's plane width equal to
    its conv width, so the fused select-mode dispatch qualifies."""

    def __init__(self, C: int, L: int, key):
        self.a0_width = C
        self.n_levels = 2
        self.widths = (C, C)
        self._mixers = tuple(
            LongConvMixer(0.5 * jax.random.normal(
                jax.random.fold_in(key, l), (L, C), jnp.float32))
            for l in range(self.n_levels))

    def mixers(self, params):
        return self._mixers

    def block(self, params, level, z, y):
        return jnp.tanh(z) + y

    def advance(self, params, a_top, rng):
        return jnp.tanh(a_top), jnp.zeros((a_top.shape[0],), jnp.int32)


def test_generic_engine_gray_impl_pallas_bitwise():
    """GenericFlashEngine end-to-end: a full fractal-schedule generation
    with gray_impl='pallas' reproduces the XLA walk bitwise (states a AND
    mixer states s), including the U=1 tiles the select-mode kernel keeps."""
    from repro.core.generic import GenericFlashEngine

    C, n = 5, 16
    states = {}
    for impl in ("xla", "pallas"):
        model = _LongConvModel(C, n, jax.random.PRNGKey(2))
        eng = GenericFlashEngine(model, {}, batch=2, gen_max=n,
                                 gray_impl=impl)
        plan = eng._gray_plan(model._mixers[0], 2, C)
        if impl == "pallas":
            assert plan is not None and plan.fused, plan
        state = eng.init_state()
        state = eng.set_first(
            state, jax.random.normal(jax.random.PRNGKey(4), (2, C)))
        state, _ = eng.generate(state, n, rng=jax.random.PRNGKey(6))
        states[impl] = state
    for l in range(len(states["xla"].a)):
        np.testing.assert_array_equal(np.asarray(states["xla"].a[l]),
                                      np.asarray(states["pallas"].a[l]))
    for l in range(len(states["xla"].s)):
        np.testing.assert_array_equal(np.asarray(states["xla"].s[l]),
                                      np.asarray(states["pallas"].s[l]))
