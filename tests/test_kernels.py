"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per instructions: sweep shapes/dtypes, assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {
    jnp.float32: dict(rtol=1e-5, atol=1e-5),
    jnp.bfloat16: dict(rtol=2e-2, atol=2e-2),
}


# -------------------------------------------------------------- tile_conv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("U", [1, 2, 4, 8, 16, 64])
@pytest.mark.parametrize("C", [1, 7, 128, 200])
def test_tile_conv_shapes_dtypes(U, C, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(U * 1000 + C))
    y = _rand(k1, (2, U, C), dtype)
    rho = _rand(k2, (2 * U, C), jnp.float32)
    got = ops.tile_conv(y, rho)
    want = ref.tile_conv_ref(y, rho)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype])


def test_tile_conv_group_batch_broadcast():
    G, B, U, C = 3, 2, 8, 5
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    y = _rand(k1, (G, B, U, C), jnp.float32)
    rho = _rand(k2, (G, 1, 2 * U, C), jnp.float32)
    got = ops.tile_conv(y, rho)
    want = ref.tile_conv_ref(y, rho)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    st.sampled_from([1, 2, 4, 8, 32]),
    st.integers(min_value=1, max_value=130),
    st.integers(min_value=1, max_value=3),
)
@settings(max_examples=12, deadline=None)
def test_tile_conv_property(U, C, B):
    k1, k2 = jax.random.split(jax.random.PRNGKey(U + C * 31 + B))
    y = _rand(k1, (B, U, C), jnp.float32)
    rho = _rand(k2, (2 * U, C), jnp.float32)
    np.testing.assert_allclose(
        ops.tile_conv(y, rho), ref.tile_conv_ref(y, rho), rtol=1e-5, atol=1e-5)


def test_tile_conv_matches_tau_direct():
    from repro.core import tau as tau_mod
    U, C = 16, 64
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    y = _rand(k1, (4, U, C), jnp.float32)
    rho = _rand(k2, (2 * U, C), jnp.float32)
    np.testing.assert_allclose(
        ops.tile_conv(y, rho), tau_mod.tau_direct(y, rho), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- short_conv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,K,block_t", [(4, 4, 128), (17, 3, 8), (128, 4, 32),
                                         (300, 4, 128)])
@pytest.mark.parametrize("C", [3, 128, 150])
def test_short_conv_shapes_dtypes(T, K, block_t, C, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(T * 7 + K + C), 3)
    x = _rand(k1, (2, T, C), dtype)
    w = _rand(k2, (K, C), jnp.float32)
    b = _rand(k3, (C,), jnp.float32)
    got = ops.short_conv(x, w, b, block_t=block_t)
    want = ref.short_conv_ref(x, w, b)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOL[dtype])


def test_short_conv_no_bias_causality():
    # Impulse response: output must not see the future.
    T, C, K = 32, 128, 4
    w = jnp.ones((K, C), jnp.float32)
    x = jnp.zeros((1, T, C)).at[0, 10].set(1.0)
    y = np.asarray(ops.short_conv(x, w))
    assert np.all(y[0, :10] == 0)           # nothing before the impulse
    assert np.all(y[0, 10:14] == 1.0)       # K taps after it
    assert np.all(y[0, 14:] == 0)


@given(st.integers(min_value=1, max_value=64), st.integers(min_value=1, max_value=4))
@settings(max_examples=10, deadline=None)
def test_short_conv_property(T, K):
    k1, k2 = jax.random.split(jax.random.PRNGKey(T * 5 + K))
    x = _rand(k1, (1, T, 16), jnp.float32)
    w = _rand(k2, (K, 16), jnp.float32)
    np.testing.assert_allclose(
        ops.short_conv(x, w), ref.short_conv_ref(x, w), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- decode_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,chunk", [(8, 8), (100, 32), (257, 64), (1024, 256)])
@pytest.mark.parametrize("K,G,hd", [(1, 1, 8), (2, 4, 16), (8, 2, 128)])
def test_decode_attention_shapes_dtypes(S, chunk, K, G, hd, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(S + K * 7 + hd), 4)
    q = _rand(ks[0], (B, K, G, hd), dtype)
    k = _rand(ks[1], (B, S, K, hd), dtype)
    v = _rand(ks[2], (B, S, K, hd), dtype)
    pos = jax.random.randint(ks[3], (B,), 1, S + 1)
    got = ops.decode_attention(q, k, v, pos, chunk=chunk)
    want = ref.decode_attention_ref(q, k, v, pos)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


def test_decode_attention_respects_validity():
    """Entries at positions >= pos must not influence the output."""
    B, K, G, hd, S = 1, 1, 2, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (B, K, G, hd), jnp.float32)
    k = _rand(ks[1], (B, S, K, hd), jnp.float32)
    v = _rand(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.asarray([17])
    base = ops.decode_attention(q, k, v, pos, chunk=16)
    # poison the invalid tail
    k2 = k.at[:, 17:].set(1e3)
    v2 = v.at[:, 17:].set(-1e3)
    poisoned = ops.decode_attention(q, k2, v2, pos, chunk=16)
    np.testing.assert_allclose(base, poisoned, rtol=1e-6, atol=1e-6)


@given(st.integers(min_value=1, max_value=96), st.sampled_from([8, 32]))
@settings(max_examples=8, deadline=None)
def test_decode_attention_property(pos_v, chunk):
    B, K, G, hd, S = 1, 2, 2, 8, 96
    ks = jax.random.split(jax.random.PRNGKey(pos_v), 3)
    q = _rand(ks[0], (B, K, G, hd), jnp.float32)
    k = _rand(ks[1], (B, S, K, hd), jnp.float32)
    v = _rand(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.asarray([pos_v])
    np.testing.assert_allclose(
        ops.decode_attention(q, k, v, pos, chunk=chunk),
        ref.decode_attention_ref(q, k, v, pos), rtol=2e-5, atol=2e-5)
