"""Device-resident chunked decode: the fused ``decode_chunk`` path must be
BIT-IDENTICAL to the per-step dispatch loop (chunking changes dispatch
granularity, not arithmetic), its jit cache must stay O(log L) over a whole
generation, and the τ dispatch bugfixes it rides with must hold:
``tau_hybrid(use_pallas=True)`` with only a precomputed DFT, and the
``tau_impl="pallas"`` route respecting ``direct_max``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tau as tau_mod
from repro.core.engine import FlashEngine
from repro.core.tiling import largest_pow2_divisor, schedule_segment
from repro.models.synthetic_lcsm import SyntheticLCSM


def _engine(strategy="flash", chunk_size=1, **kw):
    model = SyntheticLCSM(n_levels=2, d_model=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = FlashEngine(model, params, batch=2, strategy=strategy,
                      chunk_size=chunk_size, **kw)
    return model, eng


def _decode(eng, model, n, *, P=0):
    """Prefill-with-P-then-decode-n (P=0: seeded first entry, origin 0)."""
    rng = jax.random.PRNGKey(7)
    if P:
        prompt = jax.random.normal(jax.random.PRNGKey(9), (2, P, model.d))
        state, _ = eng.prefill(prompt)
        origin = P
    else:
        state = eng.init_state()
        state = eng.set_first(
            state, jax.random.normal(jax.random.PRNGKey(42), (2, model.d)))
        origin = 0
    state, toks = eng.generate(state, n, origin=origin, rng=rng)
    return state, np.asarray(toks)


# ------------------------------------------------------------- bit-identity
@pytest.mark.parametrize("P,gen_max,n,Ks", [
    (0, 16, 16, (2, 8)),   # origin 0, full pow2 schedule
    (3, 16, 11, (3, 8)),   # prompt origin, n < gen_max, unaligned chunks
    (5, 12, 12, (4,)),     # non-pow2 gen_max
])
def test_decode_chunk_bit_identical_to_stepwise(P, gen_max, n, Ks):
    """Across origins and chunk sizes (power-of-two aligned and not), the
    chunked state AND token stream must equal the per-step path bitwise.
    One stepwise reference per case, compared against every K."""
    model, e1 = _engine(chunk_size=1, gen_max=gen_max, prompt_max=P)
    s1, t1 = _decode(e1, model, n, P=P)
    for K in Ks:
        _, eK = _engine(chunk_size=K, gen_max=gen_max, prompt_max=P)
        sK, tK = _decode(eK, model, n, P=P)
        np.testing.assert_array_equal(t1, tK)
        for l in range(len(s1.a)):
            np.testing.assert_array_equal(
                np.asarray(s1.a[l]), np.asarray(sK.a[l]))
        for l in range(len(s1.b)):
            np.testing.assert_array_equal(
                np.asarray(s1.b[l]), np.asarray(sK.b[l]))


def test_decode_chunk_bit_identical_across_horizon_straddle():
    """prompt_max=0 with a real prompt eats into the pow2 buffer, so late
    tiles straddle (and some fully clear) the horizon Lbuf — the segment's
    0-entries and the in-tile clipping must reproduce the per-step guard
    exactly."""
    P, G = 3, 16
    model, e1 = _engine(chunk_size=1, gen_max=G, prompt_max=0)
    _, eK = _engine(chunk_size=4, gen_max=G, prompt_max=0)
    n = e1.Lbuf - P - 1
    assert any(p + largest_pow2_divisor(i) >= e1.Lbuf > p + 1
               for i, p in ((i, P + i - 1) for i in range(1, n))), \
        "setup must straddle the horizon"
    s1, t1 = _decode(e1, model, n, P=P)
    sK, tK = _decode(eK, model, n, P=P)
    np.testing.assert_array_equal(t1, tK)
    for l in range(len(s1.a)):
        np.testing.assert_array_equal(np.asarray(s1.a[l]), np.asarray(sK.a[l]))


@pytest.mark.parametrize("strategy", ["lazy", "eager"])
def test_decode_chunk_baseline_strategies_match_stepwise(strategy):
    """The O(L^2) baselines chunk too.  Lazy is bitwise identical; eager's
    per-step accumulation (b += y*rho) gets FMA-contracted when K steps fuse
    into one XLA program, so it is exact only to rounding."""
    n = 12
    model, e1 = _engine(strategy, chunk_size=1, gen_max=n)
    _, eK = _engine(strategy, chunk_size=4, gen_max=n)
    s1, t1 = _decode(e1, model, n)
    sK, tK = _decode(eK, model, n)
    np.testing.assert_array_equal(t1, tK)
    for l in range(len(s1.a)):
        if strategy == "lazy":
            np.testing.assert_array_equal(
                np.asarray(s1.a[l]), np.asarray(sK.a[l]))
        else:
            np.testing.assert_allclose(
                np.asarray(s1.a[l]), np.asarray(sK.a[l]),
                rtol=1e-5, atol=1e-5)


def test_chunk_jit_cache_stays_logarithmic():
    """Aligned power-of-two chunks share interior tile sides, so a whole
    generation compiles O(log L) distinct segments — not O(L/K)."""
    n, K = 32, 4
    model, eng = _engine(chunk_size=K, gen_max=n)
    _decode(eng, model, n)
    # segments: interior pattern fixed; only the last entry varies over
    # lowbit(jK+K) for j = 0..n/K-1, i.e. log2(n/K)+1 values.
    assert len(eng._jit_chunk) <= int(np.log2(n // K)) + 2, \
        f"chunk cache blew up: {list(eng._jit_chunk)}"


# --------------------------------------------------------- schedule_segment
def test_schedule_segment_matches_per_step_rules():
    """The segment must encode exactly the per-step driver's decisions:
    lowbit side, no tile at/after the last step, no tile once even the first
    output falls past the horizon."""
    origin, horizon, last = 5, 16, 9
    for start in (1, 3, 8):
        seg = schedule_segment(start, 4, origin=origin, horizon=horizon,
                               last_step=last)
        for i, side in enumerate(seg):
            r = start + i
            want = largest_pow2_divisor(r)
            if r >= last or origin + r >= horizon:
                want = 0
            assert side == want, (start, i, seg)


def test_schedule_segment_aligned_interiors_are_invariant():
    K = 8
    segs = {schedule_segment(j * K + 1, K)[:-1] for j in range(16)}
    assert len(segs) == 1  # interior entries identical for every chunk


def test_schedule_segment_rejects_bad_args():
    with pytest.raises(ValueError):
        schedule_segment(0, 4)
    with pytest.raises(ValueError):
        schedule_segment(1, 0)


# --------------------------------------------------------------- τ bugfixes
def test_tau_hybrid_pallas_with_only_rho_f():
    """Regression: use_pallas=True with a precomputed DFT and no rho2u used
    to crash with AttributeError inside kops.tile_conv (rho2u=None).  The
    filter is now reconstructed from its order-2U DFT."""
    U, C = 8, 4
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    y = jax.random.normal(k1, (2, U, C), jnp.float32)
    rho2u = jax.random.normal(k2, (2 * U, C), jnp.float32)
    rho_f = tau_mod.rho_dft(rho2u)
    want = tau_mod.tau_direct(y, rho2u)
    got = tau_mod.tau_hybrid(y, rho_f=rho_f, use_pallas=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # same guard on the non-pallas direct branch
    got2 = tau_mod.tau_hybrid(y, rho_f=rho_f, use_pallas=False)
    np.testing.assert_allclose(got2, want, rtol=1e-5, atol=1e-5)


def test_tau_hybrid_without_filter_raises_clearly():
    y = jnp.zeros((1, 4, 2))
    with pytest.raises(ValueError, match="rho2u or its DFT"):
        tau_mod.tau_hybrid(y)


@pytest.mark.parametrize("U", [1, 2, 4, 8, 16, 32, 64, 128, 256])
def test_tau_pallas_matches_direct(U):
    """τ pallas-vs-direct equivalence across the full tile-side range the
    schedule can unlock (satellite: U in 1..256)."""
    from repro.kernels import ops as kops
    C = 3
    k1, k2 = jax.random.split(jax.random.PRNGKey(U))
    y = jax.random.normal(k1, (1, U, C), jnp.float32)
    rho2u = jax.random.normal(k2, (2 * U, C), jnp.float32)
    np.testing.assert_allclose(
        kops.tile_conv(y, rho2u), tau_mod.tau_direct(y, rho2u),
        rtol=2e-5, atol=2e-5)


def test_engine_tau_pallas_respects_direct_max():
    """tau_impl='pallas' must route tiles above direct_max to the FFT path
    (the unrolled Pallas kernel is O(U^2) work and O(U) trace size), and the
    result must match the FFT evaluation it falls back to."""
    model, eng = _engine(tau_impl="pallas", direct_max=4, gen_max=8)
    U, C = 16, 4  # U > direct_max
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    y = jax.random.normal(k1, (1, 2, U, C), jnp.float32)
    rho2u = jax.random.normal(k2, (1, 1, 2 * U, C), jnp.float32)
    got = eng._tau(y, rho2u, None)
    want = tau_mod.tau_fft(y, rho2u=rho2u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # below the crossover it is the direct Pallas kernel
    U = 4
    y2 = jax.random.normal(k1, (1, 2, U, C), jnp.float32)
    r2 = jax.random.normal(k2, (1, 1, 2 * U, C), jnp.float32)
    np.testing.assert_allclose(
        eng._tau(y2, r2, None), tau_mod.tau_direct(y2, r2),
        rtol=1e-5, atol=1e-5)


def test_flash_pallas_engine_decode_matches_hybrid():
    """End-to-end: a pallas-dispatch engine decode equals the hybrid engine
    decode (τ implementations are numerically interchangeable here: both
    dispatch direct below direct_max and FFT above)."""
    n = 8
    model, ep = _engine(tau_impl="pallas", direct_max=2, gen_max=n)
    _, eh = _engine(tau_impl="hybrid", direct_max=2, gen_max=n)
    sp, tp = _decode(ep, model, n)
    sh, th = _decode(eh, model, n)
    np.testing.assert_array_equal(tp, th)
    for l in range(len(sp.a)):
        np.testing.assert_allclose(
            np.asarray(sp.a[l]), np.asarray(sh.a[l]), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------- rng-key schedule
# step_chunk's docstring (PR 2) promises: (1) the fused lockstep chunk
# splits the rng EXACTLY as the per-step loop does, so sampling models see
# identical keys; (2) the server chunk consumes one split per blind step —
# a different (but deterministic and reproducible) schedule than per-step
# serving.  These tests pin both halves so the contract can't silently rot.
class _SamplingLCSM(SyntheticLCSM):
    """advance() actually consumes its rng and leaks a key fingerprint as
    the token — the emitted stream IS the rng-key schedule."""

    def advance(self, params, acts, rng):
        nxt, _ = super().advance(params, acts, rng)
        token = jax.random.randint(rng, (nxt.shape[0],), 0, 1 << 30)
        return nxt, token.astype(jnp.int32)


def _sampling_engine(chunk_size):
    model = _SamplingLCSM(n_levels=2, d_model=4)
    params = model.init(jax.random.PRNGKey(0))
    return model, FlashEngine(model, params, batch=2, gen_max=16,
                              chunk_size=chunk_size)


def test_chunked_rng_schedule_matches_stepwise_and_reproduces():
    """Lockstep decode_chunk must consume the SAME per-step rng splits as
    the stepwise loop (the tokens are key fingerprints, so equality of
    streams is equality of key schedules), and a re-run from the same seed
    must reproduce the stream bitwise."""
    n = 16
    model, e1 = _sampling_engine(chunk_size=1)
    _, t1 = _decode(e1, model, n)
    for K in (4, 8):
        _, eK = _sampling_engine(chunk_size=K)
        _, tK = _decode(eK, model, n)
        np.testing.assert_array_equal(t1, tK)
    _, e1b = _sampling_engine(chunk_size=1)
    _, t1b = _decode(e1b, model, n)
    np.testing.assert_array_equal(t1, t1b)


def test_chunk_rng_advances_one_split_per_step():
    """decode_chunk and server_chunk return the rng advanced by EXACTLY one
    split per schedule step (len(sides) resp. K of them), matching the
    stepwise loop's split chain — the documented deterministic schedule."""
    model, eng = _sampling_engine(chunk_size=1)
    rng = jax.random.PRNGKey(3)

    state = eng.init_state()
    state = eng.set_first(
        state, jax.random.normal(jax.random.PRNGKey(1), (2, model.d)))
    sides = schedule_segment(1, 4, origin=0, horizon=eng.Lbuf, last_step=8)
    _, _, rng_out = eng.decode_chunk(state, 0, rng, sides)

    want = rng
    for _ in range(len(sides)):
        want, _ = jax.random.split(want)
    np.testing.assert_array_equal(np.asarray(rng_out), np.asarray(want))

    K = 5
    state2 = eng.init_state()
    _, _, rng_out2 = eng.server_chunk(
        state2, np.zeros(2, np.int32), np.zeros(2, np.int32),
        np.ones(2, bool), rng, K)
    want2 = rng
    for _ in range(K):
        want2, _ = jax.random.split(want2)
    np.testing.assert_array_equal(np.asarray(rng_out2), np.asarray(want2))


# ---------------------------------------------------------------- donation
def test_step_functions_donate_state():
    """The jitted step/chunk functions donate their buffers: when the
    backend honors donation (CPU/TPU do), the passed-in state is dead after
    the call — the full-state copy per token is gone."""
    model, eng = _engine(gen_max=8)
    state = eng.init_state()
    state = eng.set_first(
        state, jax.random.normal(jax.random.PRNGKey(0), (2, model.d)))
    new_state, _ = eng.red_step(state, 0, jax.random.PRNGKey(1))
    if not state.a[1].is_deleted():
        pytest.skip("backend does not honor buffer donation")
    with pytest.raises(RuntimeError):
        np.asarray(state.a[1])  # the donated input is dead
    # the returned state stays fully usable
    assert np.asarray(new_state.a[0]).shape == (2, eng.Lbuf, model.d)


# ------------------------------------------------------- fused gray dispatch
# gray_impl="pallas" swaps the gray-tile/red-pass hot path for the fused
# Pallas kernels (kernels/gray_tile.py, interpret mode on CPU).  The swap
# must be invisible: identical token streams AND state buffers, bitwise,
# through every serving entry point.
@pytest.mark.parametrize("P,gen_max,n,K", [
    (0, 16, 16, 1),    # per-step dispatch, origin 0
    (3, 16, 11, 4),    # prompt origin, fused decode chunks
])
def test_decode_gray_impl_pallas_bitwise_to_xla(P, gen_max, n, K):
    model, ex = _engine(chunk_size=K, gen_max=gen_max, prompt_max=P)
    _, ep = _engine(chunk_size=K, gen_max=gen_max, prompt_max=P,
                    gray_impl="pallas")
    sx, tx = _decode(ex, model, n, P=P)
    sp, tp = _decode(ep, model, n, P=P)
    np.testing.assert_array_equal(tx, tp)
    for l in range(len(sx.a)):
        np.testing.assert_array_equal(np.asarray(sx.a[l]), np.asarray(sp.a[l]))
    for l in range(len(sx.b)):
        np.testing.assert_array_equal(np.asarray(sx.b[l]), np.asarray(sp.b[l]))


def test_server_chunk_gray_impl_pallas_bitwise():
    """The per-slot traced-schedule server chunk (masked batched tile
    dispatch) routes through the same fused kernels — bitwise too."""
    rng = jax.random.PRNGKey(5)
    outs = {}
    for impl in ("xla", "pallas"):
        model, eng = _engine(gen_max=16, gray_impl=impl)
        state = eng.init_state()
        state = eng.set_first(
            state, jax.random.normal(jax.random.PRNGKey(42), (2, model.d)))
        p0 = np.zeros(2, np.int32)
        origin = np.zeros(2, np.int32)
        live = np.ones(2, bool)
        state, toks, _ = eng.server_chunk(state, p0, origin, live, rng, 6)
        outs[impl] = (state, np.asarray(toks))
    np.testing.assert_array_equal(outs["xla"][1], outs["pallas"][1])
    for l in range(len(outs["xla"][0].b)):
        np.testing.assert_array_equal(np.asarray(outs["xla"][0].b[l]),
                                      np.asarray(outs["pallas"][0].b[l]))


def test_small_u_gray_programs_are_fft_free():
    """Regression (τ dispatch): direct-regime tile programs must use the
    CACHED time-domain filter prefixes — passing only the cached DFT used
    to force tau_hybrid to reconstruct rho[:2U] with an irfft inside every
    traced gray program."""
    model, eng = _engine(gen_max=16)
    state = eng.init_state()
    p = jnp.full((2,), 3, jnp.int32)
    mask = jnp.ones((2,), bool)
    jaxpr = str(jax.make_jaxpr(
        lambda s, pp, mm: eng._gray_tile(None, s, pp, mm, U=4))(
            state, p, mask))
    assert "fft" not in jaxpr, "direct-regime gray program contains an FFT"
    # same pin for the generic LongConvMixer's square range_alg
    from repro.core.generic import LongConvMixer
    mix = LongConvMixer(jnp.ones((16, 3), jnp.float32))
    y = jnp.zeros((2, 4, 3), jnp.float32)
    jaxpr2 = str(jax.make_jaxpr(
        lambda y: mix.range_alg(y, 0, np.arange(1, 5)))(y))
    assert "fft" not in jaxpr2, "LongConvMixer square tile contains an FFT"


def test_fused_gray_step_donates_state():
    """The fused kernel aliases the b buffers (input_output_aliases) —
    that must compose with the step function's jit donation, not fight it:
    the donated input state dies, the returned one is usable."""
    model, eng = _engine(gen_max=8, gray_impl="pallas")
    plan = eng._gray_plan(2, model.d, [model.d, model.d])
    assert plan is not None and plan.fused, plan
    state = eng.init_state()
    state = eng.set_first(
        state, jax.random.normal(jax.random.PRNGKey(0), (2, model.d)))
    new_state = eng.gray_step(state, 1, None, U=2)
    if not state.b[0].is_deleted():
        pytest.skip("backend does not honor buffer donation")
    with pytest.raises(RuntimeError):
        np.asarray(state.b[0])
    assert np.asarray(new_state.b[0]).shape == (2, eng.Lbuf, model.d)
