"""Continuous batching for LCSM (Flash Inference) and GLA (generic §4
engine) serving backends.

The exactness bar: every per-request stream emitted by a slot-based
server — requests with independent lifetimes sharing slots, admitted
and retired mid-flight — must be identical to an isolated batch-1 lockstep
greedy decode of the same prompt (the same bar examples/serve_batched.py
asserts for the transformer backend).  The GLA section runs the mirror
trace through GenericServer: same slot logic, different mixer family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.serving import (GenericServer, LCSMServer, Request, ServingEngine,
                           make_server)
from repro.serving import generic_backend
from repro.serving.lcsm_backend import isolated_decode

PROMPT_MAX, GEN_MAX = 8, 16


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("hyena").smoke(), name="hyena-cb",
                              n_layers=4, d_model=32, d_ff=64, vocab=128)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _isolated_decode(cfg, params, prompt, n):
    return isolated_decode(cfg, params, prompt, n,
                           prompt_max=PROMPT_MAX, gen_max=GEN_MAX)


def _mixed_requests(cfg, n_reqs, seed=0):
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n_reqs):
        p_len = int(rng.randint(1, PROMPT_MAX + 1))
        reqs.append(Request(
            uid=i, prompt=rng.randint(0, cfg.vocab, (p_len,)).astype(np.int32),
            max_new=int(rng.randint(2, GEN_MAX + 1))))
    return reqs


@pytest.mark.parametrize("strategy", ["flash", "lazy"])
def test_continuous_batching_matches_isolated(setup, strategy):
    """7 requests with mixed prompt/output lengths over 3 slots: slots
    refill as requests retire, and every stream must equal its isolated
    batch-1 decode."""
    cfg, params = setup
    srv = make_server(cfg, params, n_slots=3, prompt_max=PROMPT_MAX,
                      gen_max=GEN_MAX, strategy=strategy)
    assert isinstance(srv, LCSMServer)
    reqs = _mixed_requests(cfg, 7)
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    assert len(done) == len(reqs) and all(r.done for r in reqs)
    for r in reqs:
        assert len(r.out) == r.max_new
        ref = _isolated_decode(cfg, params, r.prompt, r.max_new)
        assert r.out == ref, f"req {r.uid}: {r.out} != {ref}"


def test_slot_count_invariance(setup):
    """The number of slots must not change any request's tokens."""
    cfg, params = setup

    def run(n_slots):
        srv = make_server(cfg, params, n_slots=n_slots,
                          prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
        reqs = _mixed_requests(cfg, 6, seed=3)
        for r in reqs:
            srv.submit(r)
        srv.run()
        return {r.uid: tuple(r.out) for r in reqs}

    assert run(1) == run(3)


def test_eos_retires_slot_early(setup):
    """A request whose EOS appears mid-stream must retire at that token and
    hand its slot to the queue; other in-flight streams are unaffected."""
    cfg, params = setup
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab, (4,)).astype(np.int32)
               for _ in range(3)]
    refs = [_isolated_decode(cfg, params, p, GEN_MAX) for p in prompts]
    eos_pos = 5
    reqs = [
        Request(uid=0, prompt=prompts[0], max_new=GEN_MAX,
                eos_id=refs[0][eos_pos]),
        Request(uid=1, prompt=prompts[1], max_new=GEN_MAX),
        Request(uid=2, prompt=prompts[2], max_new=GEN_MAX),
    ]
    srv = make_server(cfg, params, n_slots=2, prompt_max=PROMPT_MAX,
                      gen_max=GEN_MAX)
    for r in reqs:
        srv.submit(r)
    srv.run()
    cut = refs[0].index(refs[0][eos_pos]) + 1  # EOS may first occur earlier
    assert reqs[0].out == refs[0][:cut]
    assert reqs[1].out == refs[1]
    assert reqs[2].out == refs[2]


def test_prompt_only_request_completes_at_admission(setup):
    """max_new=1: the whole answer comes from the prefill advance; the slot
    must be released immediately for the next queued request."""
    cfg, params = setup
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, cfg.vocab, (5,)).astype(np.int32)
    reqs = [Request(uid=0, prompt=prompt, max_new=1),
            Request(uid=1, prompt=prompt, max_new=4)]
    srv = make_server(cfg, params, n_slots=1, prompt_max=PROMPT_MAX,
                      gen_max=GEN_MAX)
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    assert len(done) == 2
    ref = _isolated_decode(cfg, params, prompt, 4)
    assert reqs[0].out == ref[:1]
    assert reqs[1].out == ref


def test_chunked_run_matches_per_step(setup):
    """Device-resident chunked stepping (step_chunk: one fused dispatch +
    one deferred token readback per K tokens) must emit exactly the per-step
    streams — including chunk sizes that misalign with request lengths and
    therefore overshoot past max_new (the blind tail is truncated on the
    host)."""
    cfg, params = setup

    def run(chunk):
        srv = make_server(cfg, params, n_slots=3, prompt_max=PROMPT_MAX,
                          gen_max=GEN_MAX, chunk=chunk)
        reqs = _mixed_requests(cfg, 6, seed=5)
        for r in reqs:
            srv.submit(r)
        done = srv.run()
        assert len(done) == len(reqs) and all(r.done for r in reqs)
        return {r.uid: tuple(r.out) for r in reqs}

    ref = run(None)       # per-step host loop
    # K=4 with mixed per-slot origins/max_new exercises both aligned and
    # overshooting retirements (max_new is odd for several requests).
    assert run(4) == ref


def test_chunked_eos_truncates_mid_chunk(setup):
    """An EOS landing inside a fused chunk must cut the stream at that
    token even though the device blindly generated the rest of the chunk."""
    cfg, params = setup
    rng = np.random.RandomState(11)
    prompt = rng.randint(0, cfg.vocab, (4,)).astype(np.int32)
    ref = _isolated_decode(cfg, params, prompt, GEN_MAX)
    eos_pos = 5  # mid-chunk for K=4 (second chunk, step 1)
    req = Request(uid=0, prompt=prompt, max_new=GEN_MAX,
                  eos_id=ref[eos_pos])
    srv = make_server(cfg, params, n_slots=2, prompt_max=PROMPT_MAX,
                      gen_max=GEN_MAX)
    srv.submit(req)
    srv.run(chunk=4)
    cut = ref.index(ref[eos_pos]) + 1  # EOS may first occur earlier
    assert req.out == ref[:cut]


def test_make_server_routes_by_family(setup, gla_setup):
    cfg, params = setup
    assert isinstance(make_server(cfg, params, n_slots=2, gen_max=8),
                      LCSMServer)
    gcfg, gparams = gla_setup
    srv = make_server(gcfg, gparams, n_slots=2, gen_max=8)
    assert isinstance(srv, GenericServer)
    assert isinstance(srv, LCSMServer)  # inherits the slot bookkeeping
    tcfg = get_config("qwen2.5-3b").smoke()
    from repro.models.lm import LM
    tparams = LM(tcfg).init(jax.random.PRNGKey(0))
    assert isinstance(
        make_server(tcfg, tparams, n_slots=2, max_seq=16,
                    cache_dtype=jnp.float32),
        ServingEngine)


# ------------------------------------------------ GLA ("and Beyond") mirror
@pytest.fixture(scope="module")
def gla_setup():
    from repro.models.gla import GLALM

    cfg = dataclasses.replace(get_config("gla").smoke(), name="gla-cb",
                              n_layers=2, d_model=32, d_ff=64, vocab=128,
                              gla_dk=8, gla_dv=32)
    params = GLALM(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _gla_isolated(cfg, params, prompt, n):
    return generic_backend.isolated_decode(
        cfg, params, prompt, n, prompt_max=PROMPT_MAX, gen_max=GEN_MAX)


def test_gla_continuous_batching_matches_isolated(gla_setup):
    """7 GLA requests with mixed prompt/output lengths over 3 slots through
    the generic engine: slots refill as requests retire, and every stream
    must equal its isolated batch-1 decode — bit for bit."""
    cfg, params = gla_setup
    srv = make_server(cfg, params, n_slots=3, prompt_max=PROMPT_MAX,
                      gen_max=GEN_MAX)
    assert isinstance(srv, GenericServer)
    reqs = _mixed_requests(cfg, 7)
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    assert len(done) == len(reqs) and all(r.done for r in reqs)
    for r in reqs:
        assert len(r.out) == r.max_new
        ref = _gla_isolated(cfg, params, r.prompt, r.max_new)
        assert r.out == ref, f"req {r.uid}: {r.out} != {ref}"


def test_gla_slot_count_invariance(gla_setup):
    """The number of GLA slots must not change any request's tokens."""
    cfg, params = gla_setup

    def run(n_slots):
        srv = make_server(cfg, params, n_slots=n_slots,
                          prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
        reqs = _mixed_requests(cfg, 6, seed=3)
        for r in reqs:
            srv.submit(r)
        srv.run()
        return {r.uid: tuple(r.out) for r in reqs}

    assert run(1) == run(3)


def test_gla_chunked_run_matches_per_step(gla_setup):
    """GenericServer.run(chunk=K): one fused dispatch + one deferred token
    readback per K tokens through server_chunk's masked per-side branches —
    streams must equal the per-step server exactly, including chunks that
    overshoot past max_new (blind tail truncated on the host)."""
    cfg, params = gla_setup

    def run(chunk):
        srv = make_server(cfg, params, n_slots=3, prompt_max=PROMPT_MAX,
                          gen_max=GEN_MAX, chunk=chunk)
        reqs = _mixed_requests(cfg, 6, seed=5)
        for r in reqs:
            srv.submit(r)
        done = srv.run()
        assert len(done) == len(reqs) and all(r.done for r in reqs)
        return {r.uid: tuple(r.out) for r in reqs}

    ref = run(None)
    assert run(4) == ref


def test_gla_eos_retires_slot_early(gla_setup):
    """EOS mid-stream retires a GLA slot at that token and hands it to the
    queue; other in-flight streams are unaffected."""
    cfg, params = gla_setup
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, cfg.vocab, (4,)).astype(np.int32)
               for _ in range(3)]
    refs = [_gla_isolated(cfg, params, p, GEN_MAX) for p in prompts]
    eos_pos = 5
    reqs = [
        Request(uid=0, prompt=prompts[0], max_new=GEN_MAX,
                eos_id=refs[0][eos_pos]),
        Request(uid=1, prompt=prompts[1], max_new=GEN_MAX),
        Request(uid=2, prompt=prompts[2], max_new=GEN_MAX),
    ]
    srv = make_server(cfg, params, n_slots=2, prompt_max=PROMPT_MAX,
                      gen_max=GEN_MAX)
    for r in reqs:
        srv.submit(r)
    srv.run()
    cut = refs[0].index(refs[0][eos_pos]) + 1  # EOS may first occur earlier
    assert reqs[0].out == refs[0][:cut]
    assert reqs[1].out == refs[1]
    assert reqs[2].out == refs[2]
