"""Property-based tests (hypothesis) on system invariants beyond the core
tiling sweeps in test_core_tiling.py."""


import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.tiling import (activation_positions_touched,
                               largest_pow2_divisor, tile_schedule)


# ------------------------------------------------------------------ tiling
@given(st.integers(min_value=1, max_value=1 << 20))
@settings(max_examples=60, deadline=None)
def test_lowbit_properties(i):
    U = largest_pow2_divisor(i)
    assert i % U == 0
    assert (i // U) % 2 == 1          # cofactor odd (U is the max power)
    assert U & (U - 1) == 0           # power of two


@given(st.integers(min_value=2, max_value=256))
@settings(max_examples=30, deadline=None)
def test_schedule_cell_count(L):
    """Tiles + diagonal must cover exactly the lower triangle's cell count
    (a pure counting identity — complements the O(L²) exact-cover test)."""
    cells = sum(t.side * t.out_side for t in tile_schedule(L))
    assert cells + L == L * (L + 1) // 2


@given(st.integers(min_value=4, max_value=14))
@settings(max_examples=11, deadline=None)
def test_touch_count_monotone_quasilinear(P):
    L = 1 << P
    t = activation_positions_touched(L)
    # O(L log L) bounds with explicit constants
    assert L - 1 <= t <= L * P


# --------------------------------------------------------------- optimizer
@given(st.floats(min_value=1e-4, max_value=1e-1),
       st.integers(min_value=1, max_value=5))
@settings(max_examples=10, deadline=None)
def test_adamw_update_is_bounded(lr, steps):
    """AdamW step size is bounded by ~lr regardless of gradient scale."""
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = AdamWConfig(lr=lr, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.zeros((4,))}
    stt = adamw_init(params)
    for s in range(steps):
        g = {"w": jnp.full((4,), 10.0 ** s)}  # wildly growing grads
        params, stt, _ = adamw_update(cfg, params, g, stt)
        assert float(jnp.max(jnp.abs(params["w"]))) <= 1.05 * lr * (s + 1)


# ----------------------------------------------------------------- serving
@given(st.integers(min_value=1, max_value=3), st.integers(min_value=1, max_value=4))
@settings(max_examples=4, deadline=None)
def test_serving_order_invariance(n_slots, n_reqs):
    """Slot count must not change any request's output tokens."""
    from repro.configs import get_config
    from repro.models.lm import LM
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2.5-3b").smoke()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab, (3,)).astype(np.int32)
               for _ in range(n_reqs)]

    def run(slots):
        eng = ServingEngine(cfg, params, n_slots=slots, max_seq=16,
                            cache_dtype=jnp.float32)
        reqs = [Request(uid=i, prompt=p, max_new=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return {r.uid: tuple(r.out) for r in reqs}

    assert run(n_slots) == run(max(1, n_slots - 1) if n_slots > 1 else n_slots + 1)


# -------------------------------------------------------------- data plane
@given(st.integers(min_value=0, max_value=1000), st.integers(min_value=1, max_value=8))
@settings(max_examples=20, deadline=None)
def test_data_host_split_partition(step, n_hosts):
    """Host shards partition the global batch for any host count that
    divides it."""
    from repro.configs import get_config
    from repro.data import SyntheticLMDataset

    cfg = get_config("qwen2.5-3b").smoke()
    B = 8
    if B % n_hosts:
        return
    full = SyntheticLMDataset(cfg, global_batch=B, seq_len=4).batch(step)["tokens"]
    parts = [SyntheticLMDataset(cfg, global_batch=B, seq_len=4,
                                host_id=h, n_hosts=n_hosts).batch(step)["tokens"]
             for h in range(n_hosts)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
