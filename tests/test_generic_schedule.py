"""Property tests for the PRODUCTION generic engine (Algorithm 4 on the
shared schedule machinery, core/generic.GenericFlashEngine).

The central invariant — every contribution cell (i, j >= i) aggregated
EXACTLY once — is proved with an instrumented "fingerprint mixer" whose
agg literally counts coverage: inputs are one-hot position markers,
cont(y,i,·) re-emits input i's marker, agg = +.  A finalized state at
position j must then be the exact indicator vector of {0..j}: a missed
(i, j) pair shows as a 0, a double-covered one as a 2 — for random pow2
horizons AND random chunk splits (the schedule's execution order/fusion
must never change coverage).  This mirrors the red/gray invariants
test_core_tiling.py pins for the LCSM path.

Also pinned here: the production engine vs the Python-loop
ReferenceGenericEngine (same mixer, same feedback), and the rng-key
schedule of the generic decode_chunk/server_chunk (one split per step —
the same contract test_decode_chunk.py pins for the LCSM engine).
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.generic import (GatedLinearAttention, GenericFlashEngine,
                                ReferenceGenericEngine)
from repro.core.tiling import schedule_segment

_F32 = jnp.float32


# ------------------------------------------------------- fingerprint mixer
class FingerprintMixer:
    """Coverage-counting P.1∧P.2 mixer over one-hot position markers:
    cont(y, i, j) = y_i for every j, agg = +, read = identity.  With
    y_i = onehot(i), the state at j accumulates exactly one unit per
    covered (i, j) cell — the aggregated state IS the coverage audit."""

    def __init__(self, dim: int):
        self.dim = dim

    def init_state(self, batch, length):
        return jnp.zeros((batch, length, self.dim), _F32)

    def cont_diag(self, y_i, i):
        return y_i.astype(_F32)

    def range_alg(self, y_seg, in_lo, out_offsets):
        s = y_seg.astype(_F32).sum(axis=1)  # (B, dim): one marker per input
        return jnp.broadcast_to(
            s[:, None], (s.shape[0], out_offsets.shape[0], self.dim))

    def agg(self, b, x):
        return b + x

    def read(self, s, y_i):
        return s

    def prefill_states(self, ys):
        return jnp.cumsum(ys.astype(_F32), axis=1)


class FingerprintModel:
    """GenericModel wrapper: block passes the coverage vector through and
    ``advance`` emits the NEXT one-hot marker from the coverage count —
    so a correct engine self-sustains the marker stream, and the emitted
    token at position p is the count p+1 (checked too)."""

    def __init__(self, dim: int):
        self.dim = dim
        self.a0_width = dim
        self.n_levels = 1
        self.widths = (dim,)
        self._mixer = FingerprintMixer(dim)

    def mixers(self, params):
        return (self._mixer,)

    def block(self, params, level, z, y):
        return z

    def advance(self, params, a_top, rng):
        count = jnp.round(a_top.sum(-1)).astype(jnp.int32)  # (B,) = p+1
        return jax.nn.one_hot(count, self.dim, dtype=_F32), count


def _staircase(n, dim):
    """Expected finalized states: row j = indicator of {0..j}."""
    return (np.arange(dim)[None, :] <= np.arange(n)[:, None]).astype(np.float32)


def _check_coverage(state, n, dim, B):
    s = np.asarray(state.s[0])  # (B, Lbuf, dim)
    want = _staircase(n, dim)
    for b in range(B):
        np.testing.assert_array_equal(
            s[b, :n], want,
            err_msg=f"slot {b}: coverage != exactly-once over {n} positions")


# --------------------------------------------------------- exactly-once
@given(st.integers(min_value=2, max_value=5),   # P: horizon L = 2^P
       st.integers(min_value=0, max_value=4))   # K = 2^k chunking
@settings(max_examples=12, deadline=None)
def test_every_contribution_aggregated_exactly_once(P, k):
    """Random pow2 L, aligned pow2 chunk sizes: after generating L tokens
    the state at every position j is EXACTLY the indicator of {0..j} —
    each (i, j) contribution aggregated once by red cells + gray tiles."""
    L = 1 << P
    K = min(1 << k, L)
    model = FingerprintModel(L)
    eng = GenericFlashEngine(model, {}, batch=2, gen_max=L, chunk_size=K)
    state = eng.set_first(eng.init_state(),
                          jax.nn.one_hot(jnp.zeros(2, jnp.int32), L))
    state, toks = eng.generate(state, L)
    _check_coverage(state, L, L, B=2)
    np.testing.assert_array_equal(
        np.asarray(toks), np.tile(np.arange(1, L + 1), (2, 1)))


@given(st.integers(min_value=2, max_value=5),    # P: L = 2^P
       st.integers(min_value=0, max_value=10**6))  # split-pattern seed
@settings(max_examples=12, deadline=None)
def test_random_chunk_splits_cover_exactly_once(P, seed):
    """Coverage must be split-invariant: drive decode_chunk directly with a
    RANDOM partition of the step range (not just aligned pow2 chunks) —
    the segment metadata plus in-tile clipping must still aggregate every
    cell exactly once and bit-reproduce the one-chunk run."""
    L = 1 << P
    rng = np.random.RandomState(seed)
    model = FingerprintModel(L)

    def run(splits):
        eng = GenericFlashEngine(model, {}, batch=1, gen_max=L)
        st_ = eng.set_first(eng.init_state(),
                            jax.nn.one_hot(jnp.zeros(1, jnp.int32), L))
        key = jax.random.PRNGKey(0)
        step = 0
        for k in splits:
            sides = schedule_segment(step + 1, k, origin=0,
                                     horizon=eng.Lbuf, last_step=L)
            st_, _, key = eng.decode_chunk(st_, step, key, sides)
            step += k
        return st_

    splits = []
    left = L
    while left:
        k = int(rng.randint(1, left + 1))
        splits.append(k)
        left -= k
    state = run(splits)
    _check_coverage(state, L, L, B=1)
    ref = run([L])  # single fused chunk
    np.testing.assert_array_equal(np.asarray(state.s[0]), np.asarray(ref.s[0]))


@given(st.integers(min_value=1, max_value=4),   # K server chunk size
       st.integers(min_value=0, max_value=10**6))
@settings(max_examples=8, deadline=None)
def test_server_chunks_cover_exactly_once_per_slot(K, seed):
    """Per-slot schedules through the masked-cond server path: 3 slots
    admitted with DIFFERENT prompt lengths (prefill_slot writes the prompt
    staircase + spill), then advanced in fused K-chunks — every slot's
    coverage must stay exactly-once across its own origin-shifted
    schedule."""
    L = 16
    rng = np.random.RandomState(seed)
    plens = [int(rng.randint(1, 7)) for _ in range(3)]
    gen = [int(8 + rng.randint(0, 5)) for _ in range(3)]
    model = FingerprintModel(64)
    eng = GenericFlashEngine(model, {}, batch=3, gen_max=L,
                             prompt_max=8)
    state = eng.init_state()
    for s_i, P in enumerate(plens):
        prompt = jax.nn.one_hot(jnp.arange(P), 64, dtype=_F32)[None]
        state, tok = eng.prefill_slot(state, s_i, prompt)
        assert int(tok) == P  # prefill advance reads the full prompt count
    pos = list(plens)
    key = jax.random.PRNGKey(1)
    steps_left = list(gen)
    while any(s > 0 for s in steps_left):
        p0 = np.asarray(pos, np.int32)
        live = np.asarray([s > 0 for s in steps_left], bool)
        state, toks, key = eng.server_chunk(
            state, p0, np.asarray(plens, np.int32), live, key, K)
        toks = np.asarray(toks)
        for s_i in range(3):
            if live[s_i]:
                kk = min(K, steps_left[s_i])
                # emitted counts continue the per-slot staircase
                np.testing.assert_array_equal(
                    toks[s_i, :kk],
                    np.arange(pos[s_i] + 1, pos[s_i] + kk + 1))
                pos[s_i] += K  # blind advance, like the server
                steps_left[s_i] -= K
    s0 = np.asarray(state.s[0])
    for s_i in range(3):
        n = plens[s_i] + gen[s_i]
        np.testing.assert_array_equal(
            s0[s_i, :n], _staircase(n, 64),
            err_msg=f"slot {s_i} (P={plens[s_i]}, gen={gen[s_i]})")


# ------------------------------------- production engine vs slow reference
def test_production_engine_matches_reference_runner():
    """The jitted engine must reproduce the Python-loop ReferenceGenericEngine
    under identical autoregressive feedback (GLA mixer, tanh readout):
    same input stream, same outputs, to float tolerance."""
    D, dk, dv, L = 12, 4, 6, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    mixer = GatedLinearAttention(
        wq=jax.random.normal(ks[0], (D, dk), _F32),
        wk=jax.random.normal(ks[1], (D, dk), _F32),
        wv=jax.random.normal(ks[2], (D, dv), _F32), lam=0.9)
    W = jax.random.normal(ks[3], (dv, D), _F32) * 0.3
    y0 = jax.random.normal(jax.random.PRNGKey(5), (1, D), _F32)

    ref_eng = ReferenceGenericEngine(mixer, batch=1, length=L)
    ys_ref, zs_ref = ref_eng.run(lambda zs, z: jnp.tanh(z @ W), y0)

    class M:
        a0_width = D
        n_levels = 1
        widths = (dv,)

        def mixers(self, params):
            return (mixer,)

        def block(self, params, level, z, y):
            return z

        def advance(self, params, a_top, rng):
            return jnp.tanh(a_top @ W), jnp.zeros((a_top.shape[0],), jnp.int32)

    eng = GenericFlashEngine(M(), {}, batch=1, gen_max=L, chunk_size=4)
    state = eng.set_first(eng.init_state(), y0)
    state, _ = eng.generate(state, L)
    np.testing.assert_allclose(np.asarray(state.a[0][:, :L]),
                               np.asarray(ys_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.a[1][:, :L]),
                               np.asarray(zs_ref), rtol=1e-5, atol=1e-5)


# --------------------------------------------------------- rng-key schedule
def test_generic_chunk_rng_advances_one_split_per_step():
    """decode_chunk and server_chunk return the rng advanced by EXACTLY one
    split per schedule step, matching the stepwise loop's split chain —
    the same deterministic contract the LCSM engine pins."""
    model = FingerprintModel(16)
    eng = GenericFlashEngine(model, {}, batch=2, gen_max=16)
    rng = jax.random.PRNGKey(3)
    state = eng.set_first(eng.init_state(),
                          jax.nn.one_hot(jnp.zeros(2, jnp.int32), 16))
    sides = schedule_segment(1, 4, origin=0, horizon=eng.Lbuf, last_step=8)
    _, _, rng_out = eng.decode_chunk(state, 0, rng, sides)
    want = rng
    for _ in range(len(sides)):
        want, _ = jax.random.split(want)
    np.testing.assert_array_equal(np.asarray(rng_out), np.asarray(want))

    K = 5
    state2 = eng.set_first(eng.init_state(),
                           jax.nn.one_hot(jnp.zeros(2, jnp.int32), 16))
    _, _, rng_out2 = eng.server_chunk(
        state2, np.zeros(2, np.int32), np.zeros(2, np.int32),
        np.ones(2, bool), rng, K)
    want2 = rng
    for _ in range(K):
        want2, _ = jax.random.split(want2)
    np.testing.assert_array_equal(np.asarray(rng_out2), np.asarray(want2))


def test_generic_chunk_jit_cache_stays_logarithmic():
    """Aligned pow2 chunks share interior tile sides through the segment
    cache — O(log L) distinct fused programs, exactly like the LCSM path."""
    n, K = 32, 4
    model = FingerprintModel(n)
    eng = GenericFlashEngine(model, {}, batch=1, gen_max=n, chunk_size=K)
    state = eng.set_first(eng.init_state(),
                          jax.nn.one_hot(jnp.zeros(1, jnp.int32), n))
    eng.generate(state, n)
    assert len(eng._jit_chunk) <= int(np.log2(n // K)) + 2, \
        f"chunk cache blew up: {list(eng._jit_chunk)}"


def test_generic_step_functions_donate_state():
    """Generic engine step/chunk functions donate their pytree state, like
    the LCSM engine: the passed-in buffers are dead after the call."""
    import pytest

    model = FingerprintModel(8)
    eng = GenericFlashEngine(model, {}, batch=1, gen_max=8)
    state = eng.set_first(eng.init_state(),
                          jax.nn.one_hot(jnp.zeros(1, jnp.int32), 8))
    new_state, _ = eng.red_step(state, 0, jax.random.PRNGKey(1))
    if not state.s[0].is_deleted():
        pytest.skip("backend does not honor buffer donation")
    with pytest.raises(RuntimeError):
        np.asarray(state.s[0])
    assert np.asarray(new_state.s[0]).shape == (1, eng.Lbuf, 8)
