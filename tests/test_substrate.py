"""Substrate tests: data determinism, optimizer, checkpointing, trainer,
serving engine (continuous batching exactness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.models.lm import LM
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr


def test_data_deterministic_and_host_sharded():
    cfg = get_config("qwen2.5-3b").smoke()
    d1 = SyntheticLMDataset(cfg, global_batch=4, seq_len=8, seed=3)
    d2 = SyntheticLMDataset(cfg, global_batch=4, seq_len=8, seed=3)
    np.testing.assert_array_equal(d1.batch(5)["tokens"], d2.batch(5)["tokens"])
    assert not np.array_equal(d1.batch(5)["tokens"], d1.batch(6)["tokens"])
    # two-host split reproduces the single-host global batch
    h0 = SyntheticLMDataset(cfg, global_batch=4, seq_len=8, seed=3, host_id=0, n_hosts=2)
    h1 = SyntheticLMDataset(cfg, global_batch=4, seq_len=8, seed=3, host_id=1, n_hosts=2)
    full = d1.batch(2)["tokens"]
    np.testing.assert_array_equal(
        np.concatenate([h0.batch(2)["tokens"], h1.batch(2)["tokens"]]), full)
    # targets are tokens shifted by one
    b = d1.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    st = adamw_init(params)
    for _ in range(60):
        g = {"w": 2 * params["w"]}
        params, st, _ = adamw_update(cfg, params, g, st)
    assert float(jnp.abs(params["w"]).max()) < 0.4


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0 and abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] == pytest.approx(0.1, abs=1e-6)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # monotone decay


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,), jnp.int32), jnp.full((1,), 7.0))}
    save_checkpoint(str(tmp_path), 42, tree)
    assert latest_step(str(tmp_path)) == 42
    like = jax.tree.map(jnp.zeros_like, tree)
    out = restore_checkpoint(str(tmp_path), 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_trainer_loss_decreases():
    from repro.train_loop import Trainer

    cfg = get_config("qwen2.5-3b").smoke()
    tr = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    # overfit a single repeated batch: loss must fall substantially
    class OneBatch:
        def __init__(self, cfg):
            self._b = SyntheticLMDataset(cfg, global_batch=4, seq_len=16).batch(0)
        def batch(self, step):
            return self._b
    hist = tr.fit(OneBatch(cfg), 40, log_every=39, log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_serving_continuous_batching_matches_forward():
    """Requests admitted at different times into different slots must emit
    exactly the tokens a lone greedy decode would."""
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen2.5-3b").smoke()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=2, max_seq=32,
                        cache_dtype=jnp.float32)

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=(p,)).astype(np.int32)
               for p in (3, 5, 4)]
    reqs = [Request(uid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3 and all(len(r.out) == 6 for r in done)

    # reference: sequential greedy decode per prompt
    for r in reqs:
        toks = list(r.prompt)
        for _ in range(6):
            batch = {"tokens": jnp.asarray(np.asarray(toks, np.int32))[None]}
            hidden, _ = model.forward(params, batch)
            lg = model.logits(params, hidden[:, -1])
            toks.append(int(jnp.argmax(lg[0])))
        assert toks[len(r.prompt):] == r.out, f"req {r.uid} diverged"


def test_lcsm_server_generates():
    from repro.serving import LCSMServer

    cfg = get_config("hyena").smoke()
    from repro.models.hyena import HyenaLCSM
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    srv = LCSMServer(cfg, params, batch=2, gen_max=8)
    toks = srv.generate(None, 8)
    assert toks.shape == (2, 8)
    # prompt path
    prompts = np.zeros((2, 3), np.int32)
    toks2 = srv.generate(prompts, 5)
    assert toks2.shape == (2, 5)
