"""FC006 clean twins: toggles scoped in fixtures, not at import scope."""
import jax
import pytest


@pytest.fixture
def x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def test_uses(x64):
    assert True
