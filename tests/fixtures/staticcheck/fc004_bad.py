"""FC004: lax.cond reachable from a hot-dispatch root."""
import jax


class Walker:
    def server_chunk(self, state, pv):
        return self._impl(state, pv)

    def _impl(self, state, pv):
        for U in (1, 2, 4):
            state = self._tile(state, pv, U)
        return state

    def _tile(self, state, pv, U):
        return jax.lax.cond(pv.any(), lambda s: s + U, lambda s: s, state)  # FC004
