"""FC002: dynamic_slice-family start tuples mixing host and traced ints."""
import jax


def mixed_literal_and_traced(x, pos):
    return jax.lax.dynamic_slice(x, (0, pos), (1, 4))  # FC002


def mixed_host_attr_and_traced(x, pos, spec):
    start = (spec.conv_start, pos)
    return jax.lax.dynamic_slice(x, start, (1, 4))  # FC002


def update_concat_mixed(buf, val, q):
    return jax.lax.dynamic_update_slice(buf, val, (0, q) + (0,) * 2)  # FC002
