"""FC004 clean twins: whitelisted reference ladder + unreachable cond."""
import jax
import jax.numpy as jnp


class Walker:
    def server_chunk(self, state, pv):
        return self._impl(state, pv)

    def _impl(self, state, pv):
        for U in (1, 2, 4):
            state = self._server_tiles_reference(state, pv, U)
        return self._masked(state, pv, 1)

    def _server_tiles_reference(self, state, pv, U):
        # The whitelisted exactness reference — the ONE place cond lives.
        return jax.lax.cond(pv.any(), lambda s: s + U, lambda s: s, state)

    def _masked(self, state, pv, U):
        return jnp.where(pv[:, None] > 0, state + U, state)


def offline_tool(state, flag):
    # cond in a function NOT reachable from any hot-dispatch root.
    return jax.lax.cond(flag, lambda s: s, lambda s: s * 2, state)
