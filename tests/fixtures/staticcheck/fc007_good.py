"""FC007 clean twins: instrumentation on the host side of the dispatch
boundary only — the traced bodies never touch repro.obs or a callback."""
import jax

from repro.obs import trace as _obs


class Walker:
    def server_chunk(self, state, pv, live, rng):
        # HOST wrapper: obs calls around the dispatch are the sanctioned
        # pattern — one attr load + None test when tracing is off.
        rec = _obs.RECORDER
        t0 = _obs.perf_now() if rec is not None else 0.0
        out = self._server_chunk_impl(self.params, state, pv, pv, live, rng)
        if rec is not None:
            rec.add_span("engine.server_chunk", "engine", t0, _obs.perf_now())
            rec.inc_counter("flash_dispatch_total", kind="server_chunk")
        return out

    def _server_chunk_impl(self, params, state, pv, origin, live, rng):
        return self._tiles(params, state, pv)

    def _tiles(self, params, state, pv):
        return state + 1


def offline_probe(state):
    # io_callback in a function NOT reachable from any traced root.
    return jax.experimental.io_callback(print, None, state)
