"""FC003: contractions in a mul+sum-pinned mixer module (the test mounts
this file at a pinned path)."""
import jax.numpy as jnp


def read(s, q):
    return jnp.einsum("bkd,bk->bd", s, q)  # FC003


def cont(a, b):
    return a @ b  # FC003


def agg(h, w):
    return jnp.dot(h, w)  # FC003
