"""FC003 clean twins: the pinned elementwise mul + sum contraction idiom."""


def read(s, q):
    return (s * q[..., None]).sum(axis=1)


def cont(a, b):
    return (a[..., None] * b[:, None, :]).sum(axis=-1)
