"""FC005 clean twins: normalized segment keys, pow2 buckets, bounded memo."""
import functools


def ceil_pow2(x):
    return 1 << (int(x) - 1).bit_length()


class Engine:
    def __init__(self):
        self._jit_chunk = {}
        self._jit_gray = {}

    def chunk(self, sides, fn):
        sides = tuple(int(u) for u in sides)
        self._jit_chunk[sides] = fn
        return fn

    def gray(self, U, fn):
        self._jit_gray[ceil_pow2(U)] = fn
        return fn


@functools.lru_cache(maxsize=32)
def compiled(block_t: int):
    return block_t
