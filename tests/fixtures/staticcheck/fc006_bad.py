"""FC006: global config toggles at test-module import scope."""
import os

import jax

jax.config.update("jax_enable_x64", True)  # FC006
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"  # FC006


def test_something():
    assert True
