"""FC001 clean twins: donated state is always rebound before any read."""
import jax


def rebind(eng, state, rng):
    state, tok = eng.decode_chunk(state, 0, rng, (1, 2))
    return state.a[0] + tok


def loop_threaded(eng, state, rng):
    toks = []
    for i in range(4):
        state, tok = eng.red_step(state, i, rng)
        toks.append(tok)
    return state, toks


def jit_rebound(fn, params, state, rng):
    step = jax.jit(fn, donate_argnums=(1,))
    state, out = step(params, state, rng)
    return state.b + out


def free_function_same_name(params, streams, b, pos, rho0):
    # Plain-name call to a pure function reusing a registry method name
    # (the launch/lcsm_steps idiom) — does NOT donate.
    streams2, b2, tok = red_step(params, streams, b, pos, rho0)
    return streams.shape, streams2, b2, tok


def red_step(params, streams, b, pos, rho0):
    return streams, b, 0
