"""FC005: jit cache dicts keyed by unbounded values (the test mounts this
file at a src/ path so the lru_cache arm applies)."""
import functools


class Engine:
    def __init__(self):
        self._jit_chunk = {}
        self._program_cache = {}

    def chunk(self, sides, fn):
        self._jit_chunk[sides] = fn  # FC005
        return fn

    def lookup(self, key, fn):
        self._program_cache[key] = fn  # FC005
        return fn


@functools.lru_cache(maxsize=None)  # FC005
def compiled(block_t: int):
    return block_t
