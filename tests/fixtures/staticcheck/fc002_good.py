"""FC002 clean twins: the hardened start-tuple idioms."""
import jax
import jax.numpy as jnp


def _starts(pos, *parts):
    dt = jnp.asarray(pos).dtype
    return tuple(jnp.asarray(p, dt) for p in parts)


def helper_routed(x, pos):
    return jax.lax.dynamic_slice(x, _starts(pos, 0, pos), (1, 1, 4))


def all_host(x, spec):
    B, P, _ = x.shape
    return jax.lax.dynamic_slice(x, (0, 0, spec.conv_start), (B, P, 4))


def all_traced(x, p, q):
    return jax.lax.dynamic_slice(x, (p, q), (1, 4))


def annotated_host_scalar(big, one, slot: int):
    return jax.lax.dynamic_update_slice(big, one, (0, slot) + (0,) * 2)
