"""FC001 use-after-donate: every marked line reads a donated buffer."""
import jax


def use_after_donate(eng, state, rng):
    new_state, tok = eng.decode_chunk(state, 0, rng, (1, 2))
    return state.a[0] + tok, new_state  # FC001


def loop_wraparound(eng, state0, rng):
    state = state0
    total = None
    for i in range(4):
        total = state.pos + i  # FC001
        eng.red_step(state, i, rng)
    return total


def jit_table_inferred(fn, params, state, rng):
    step = jax.jit(fn, donate_argnums=(1,))
    out = step(params, state, rng)
    return out, state.b  # FC001
