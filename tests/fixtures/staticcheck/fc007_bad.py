"""FC007: host callbacks / repro.obs reachable from traced hot bodies."""
import jax


class Walker:
    def _server_chunk_impl(self, params, state, pv, origin, live, rng):
        state = self._tiles(params, state, pv)
        return self._log_state(state)

    def _tiles(self, params, state, pv):
        # a debug print traced into the chunk program: host execution
        # baked into the jitted computation
        jax.debug.print("pv = {}", pv)  # FC007
        return state + 1

    def _log_state(self, state):
        jax.experimental.io_callback(print, None, state)  # FC007
        return state

    def _red_pass(self, params, state, p, rng):
        from repro.obs import trace as _obs  # FC007
        state = jax.pure_callback(lambda s: s, state, state)  # FC007
        return state, _obs
