"""Tiling schedule + τ implementation correctness (paper §3.1, Lemma 1,
Propositions 1-2, Appendix C)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import tau as tau_mod
from repro.core import tiling

# NOTE: do NOT disable x64 here — pytest imports every module at collection
# time, so a global jax.config.update would silently turn the CI x64 matrix
# leg back into the default-dtype suite.  Tests pin dtypes explicitly.


# ----------------------------------------------------------------- schedule
@pytest.mark.parametrize("L", [2, 4, 8, 16, 64, 128])
def test_tiling_covers_exactly_once(L):
    tiling.validate_tiling(L)


@given(st.integers(min_value=2, max_value=96))
@settings(max_examples=25, deadline=None)
def test_tiling_covers_non_pow2(L):
    tiling.validate_tiling(L)


# ------------------------------------------------- schedule properties
# (randomized invariants, not hand-picked cases: the hypothesis shim in
# _hypothesis_compat draws deterministic seeded examples when hypothesis
# itself is absent, so these run everywhere.)
@given(st.integers(min_value=1, max_value=8))
@settings(max_examples=8, deadline=None)
def test_red_steps_finalize_each_position_exactly_once(P):
    """Every output position is finalized by exactly the red pass: no gray
    tile ever touches a diagonal cell (tiles are strictly causal,
    in_hi < out_lo, so every cell they cover has i < z), and the full
    cell-coverage audit (validate_tiling: each off-diagonal contribution
    covered exactly once, causally) holds for random pow2 L — not just the
    hand-picked parametrize list above."""
    L = 1 << P
    for t in tiling.tile_schedule(L):
        assert t.in_hi < t.out_lo
    tiling.validate_tiling(L)  # exact single coverage, O(L^2) audit


@given(st.integers(min_value=1, max_value=9))
@settings(max_examples=9, deadline=None)
def test_each_gray_tile_unlocked_exactly_once(P):
    """For L = 2^P the schedule unlocks exactly one gray tile per step
    i in [1, L) — side 2^nu(i), input block ending at i, output block
    starting at i+1, unclipped (out_side == side) — and distinct tiles
    never share an output block."""
    L = 1 << P
    tiles = list(tiling.tile_schedule(L))
    assert [t.step for t in tiles] == list(range(1, L))
    out_blocks = set()
    for t in tiles:
        assert t.side == tiling.largest_pow2_divisor(t.step)
        assert t.out_side == t.side  # pow2 L: tiles fit exactly
        assert (t.in_hi, t.out_lo) == (t.step, t.step + 1)
        block = (t.out_lo, t.out_hi)
        assert block not in out_blocks, f"output block {block} written twice"
        out_blocks.add(block)


@given(st.integers(min_value=2, max_value=9),   # L = 2^P
       st.integers(min_value=0, max_value=5))   # K = 2^k
@settings(max_examples=30, deadline=None)
def test_schedule_segment_partitions_schedule(P, k):
    """Concatenating aligned K-chunks of schedule_segment over a whole
    generation partitions the step range [1, L): every step appears in
    exactly one segment slot, with its lowbit side, and slots at/after the
    last step carry 0 (no tile runs there)."""
    L = 1 << P
    K = min(1 << k, L)
    covered = {}
    j = 0
    while j * K + 1 <= L:
        seg = tiling.schedule_segment(j * K + 1, K, last_step=L)
        for i, side in enumerate(seg):
            r = j * K + 1 + i
            assert r not in covered, f"step {r} covered twice"
            covered[r] = side
        j += 1
    assert sorted(covered) == list(range(1, j * K + 1))
    for r, side in covered.items():
        want = tiling.largest_pow2_divisor(r) if r < L else 0
        assert side == want, (L, K, r, side, want)


def test_tile_histogram_matches_proposition_1():
    # Proposition 1: 2^(P-1-q) tiles of side 2^q.
    L = 256
    hist = tiling.tile_histogram(L)
    P = 8
    for q in range(P):
        assert hist[1 << q] == 1 << (P - 1 - q)


def test_tile_size_percentile_claim():
    # §5.1: 93.75% of positions use tile side U <= 8.
    L = 4096
    hist = tiling.tile_histogram(L)
    small = sum(n for u, n in hist.items() if u <= 8)
    frac = small / sum(hist.values())
    assert abs(frac - 0.9375) < 0.0005


def test_flops_model_quasilinear():
    # FLOPs(2L)/FLOPs(L) -> ~2 * (log(2L)/log L)^2 << 4 (the quadratic ratio).
    f1 = tiling.theoretical_tau_flops(1 << 12)
    f2 = tiling.theoretical_tau_flops(1 << 13)
    n1 = tiling.naive_flops(1 << 12)
    n2 = tiling.naive_flops(1 << 13)
    assert f2 / f1 < 2.6
    assert n2 / n1 > 3.9
    assert f1 < n1  # already ahead at 4k


def test_activation_touch_quasilinear():
    L = 1 << 14
    touched = tiling.activation_positions_touched(L)
    assert touched < 2 * L * np.log2(L)  # O(L log L)
    assert touched > L  # sanity


# ------------------------------------------------------------------------ τ
def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("U", [1, 2, 4, 8, 32, 128])
@pytest.mark.parametrize("C", [1, 3, 8])
def test_tau_fft_matches_direct(U, C):
    k1, k2 = jax.random.split(jax.random.PRNGKey(U * 100 + C))
    y = _rand(k1, 2, U, C)  # batch 2
    rho = _rand(k2, 2 * U, C)
    out_d = tau_mod.tau_direct(y, rho)
    out_f = tau_mod.tau_fft(y, rho2u=rho)
    np.testing.assert_allclose(out_d, out_f, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("U", [4, 64])
def test_tau_precomputed_dft_path(U):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    y = _rand(k1, 3, U, 5)
    rho = _rand(k2, 4 * U, 5)  # long filter; prefix used
    dfts = tau_mod.make_rho_dfts(rho, U)
    out = tau_mod.tau_fft(y, rho_f=dfts[U])
    ref = tau_mod.tau_direct(y, rho[: 2 * U])
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_tau_equals_definition():
    """out[t] = sum_s y[s] * rho[U + t - s] — checked against a python loop."""
    U, C = 8, 2
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    y = np.asarray(_rand(k1, 1, U, C))
    rho = np.asarray(_rand(k2, 2 * U, C))
    want = np.zeros((1, U, C), np.float32)
    for t in range(U):
        for s in range(U):
            want[0, t] += y[0, s] * rho[U + t - s]
    got = tau_mod.tau_direct(jnp.asarray(y), jnp.asarray(rho))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    st.integers(min_value=1, max_value=20),  # l
    st.integers(min_value=0, max_value=12),  # r - l
    st.integers(min_value=0, max_value=10),  # l' - r
    st.integers(min_value=0, max_value=12),  # r' - l'
)
@settings(max_examples=40, deadline=None)
def test_tau_ranges_lemma1(l, dr, dlp, drp):
    r = l + dr
    lp = r + dlp
    rp = lp + drp
    L = rp + 4
    key = jax.random.PRNGKey(l * 7 + dr * 5 + dlp * 3 + drp)
    k1, k2 = jax.random.split(key)
    y = _rand(k1, 1, L, 2)
    rho = _rand(k2, L, 2)
    got = np.asarray(tau_mod.tau_ranges(y, rho, l, r, lp, rp))
    yn, rn = np.asarray(y), np.asarray(rho)
    want = np.zeros((1, rp - lp + 1, 2), np.float32)
    for t in range(lp, rp + 1):
        for i in range(l, r + 1):
            want[0, t - lp] += yn[0, i - 1] * rn[t - i]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("T,out_len", [(8, 8), (16, 16), (8, 32), (5, 12)])
def test_conv_causal_fft_vs_direct(T, out_len):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    y = _rand(k1, 2, T, 4)
    rho = _rand(k2, out_len, 4)
    got = tau_mod.conv_causal_fft(y, rho[None], out_len=out_len)
    yn, rn = np.asarray(y), np.asarray(rho)
    want = np.zeros((2, out_len, 4), np.float32)
    for t in range(out_len):
        for s in range(min(T, t + 1)):
            want[:, t] += yn[:, s] * rn[t - s]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_tau_broadcast_group_axis():
    """Stacked levels (G,1,2U,C) filters vs (G,B,U,C) inputs broadcast."""
    G, B, U, C = 3, 2, 4, 5
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    y = _rand(k1, G, B, U, C)
    rho = _rand(k2, G, 1, 2 * U, C)
    d = tau_mod.tau_direct(y, rho)
    f = tau_mod.tau_fft(y, rho2u=rho)
    assert d.shape == (G, B, U, C)
    np.testing.assert_allclose(d, f, rtol=1e-4, atol=1e-4)
    for g in range(G):
        ref = tau_mod.tau_direct(y[g], rho[g, 0])
        np.testing.assert_allclose(d[g], ref, rtol=1e-5, atol=1e-5)
