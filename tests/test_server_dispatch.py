"""Batched gather/scatter server dispatch vs the retired cond ladder.

PR 6 replaced the serving hot loop's per-side ``lax.cond`` ladder (and the
server-step path's per-(slot, side) host grouping) with ONE batched
mask-select dispatch: every possible tile side computed unconditionally on
gathered per-slot rows, merged by mask (``ScheduleWalker.
_server_tiles_batched``).  The ladder survives as ``dispatch="reference"``
precisely so this module can pin the new path against it:

* **tile-dispatch property** — for RANDOMIZED states, per-slot positions,
  origins, and live masks, one batched tile pass equals one reference
  ladder pass, for both engines (LCSM FlashEngine + generic
  GenericFlashEngine).
* **fused-chunk property** — ``server_chunk(dispatch="batched")`` vs
  ``"reference"`` across randomized chunk sizes and per-slot schedules:
  token streams BITWISE identical, final states equal.
* **server-level** — LCSMServer / GenericServer running whole mixed
  traces under ``engine.server_dispatch = "reference"`` emit exactly the
  batched server's streams, per-step and chunked.

Exactness grain: token streams (int32) are compared bitwise everywhere.
Generic-engine states are compared bitwise too (``_apply_tile`` merges by
select, so a masked-out row keeps its old value exactly).  LCSM states
are compared under IEEE == (``np.array_equal``): the batched path's
masked scatter-ADD contributes +0.0 where the ladder skips, which maps a
stored -0.0 to +0.0 in the b accumulators — numerically invisible, and
tokens never differ (see ``_server_tiles_batched``'s docstring).

Everything here is single-device math, so the module runs unchanged under
the forced-4-device CI leg (``XLA_FLAGS=
--xla_force_host_platform_device_count=4``); the one mesh-gated test
additionally pins batched == reference THROUGH a data-sharded server —
the configuration whose cond-predicate syncs motivated the refactor.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.engine import FlashEngine
from repro.models.synthetic_lcsm import SyntheticLCSM

B = 6  # slots: enough to populate several side groups at once


# ----------------------------------------------------------- shared helpers
def _rand_state(eng, seed: int):
    """A fresh state pytree with every float leaf filled from seeded
    normals (int leaves, if any, kept).  The dispatch equivalence is a
    pure-function property, so arbitrary buffer contents are fair game —
    wider than any reachable serving state."""
    leaves, treedef = jax.tree.flatten(eng.init_state())
    keys = jax.random.split(jax.random.PRNGKey(seed), max(len(leaves), 1))
    out = []
    for leaf, k in zip(leaves, keys):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(jax.random.normal(k, leaf.shape, jnp.float32)
                       .astype(leaf.dtype))
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def _rand_schedule(eng, seed: int, overshoot: int = 2):
    """Random per-slot (pv, origin, live): origins in [0, prompt_max],
    positions from origin (rel step >= 1) up to slightly PAST the horizon
    — the blind-overshoot region dispatch_chunk steps retired slots
    through — and a ~70% live mask (occasionally all-False: every side's
    group empty, the ladder skips everything)."""
    rng = np.random.RandomState(seed)
    pmax = 4  # both engine fixtures are built with prompt_max=4
    origin = rng.randint(0, pmax + 1, B).astype(np.int32)
    pv = np.asarray(
        [int(rng.randint(o, eng.Lbuf + overshoot)) for o in origin],
        np.int32)
    live = rng.rand(B) < 0.7
    return (jnp.asarray(pv), jnp.asarray(origin), jnp.asarray(live))


def _assert_states_equal(ref, got, *, bitwise: bool, msg: str):
    rl, _ = jax.tree.flatten(ref)
    gl, _ = jax.tree.flatten(got)
    assert len(rl) == len(gl)
    for i, (r, g) in enumerate(zip(rl, gl)):
        r, g = np.asarray(r), np.asarray(g)
        if bitwise:
            assert r.tobytes() == g.tobytes(), f"leaf {i} differs ({msg})"
        else:
            np.testing.assert_array_equal(r, g,
                                          err_msg=f"leaf {i} ({msg})")


# ------------------------------------------------------------ LCSM fixtures
@functools.lru_cache(maxsize=None)
def _lcsm_engine():
    model = SyntheticLCSM(n_levels=2, d_model=8)
    params = model.init(jax.random.PRNGKey(0))
    return FlashEngine(model, params, batch=B, gen_max=16, prompt_max=4)


@functools.lru_cache(maxsize=None)
def _gla_engine():
    from repro.configs import get_config
    from repro.core.generic import GenericFlashEngine
    from repro.models.gla import GLALM

    cfg = dataclasses.replace(
        get_config("gla").smoke(), name="gla-dispatch",
        n_layers=2, d_model=16, d_ff=32, vocab=64, gla_dk=4, gla_dv=8)
    model = GLALM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return GenericFlashEngine(model, params, batch=B, gen_max=16,
                              prompt_max=4)


_ENGINES = {"lcsm": (_lcsm_engine, False),  # (factory, bitwise states)
            "gla": (_gla_engine, True)}


# ----------------------------------------------- tile-dispatch equivalence
@functools.lru_cache(maxsize=None)
def _jit_tiles(engine_name: str, dispatch: str):
    """COMPILED tile pass — the form every serving path actually runs
    (tiles_step / server_chunk are jitted).  Comparing the compiled
    programs is the contract; eager op-by-op execution rounds the same
    arithmetic differently than XLA's fused codegen (1-ulp FMA effects),
    for the reference ladder just as for the batched path."""
    factory, _ = _ENGINES[engine_name]
    eng = factory()
    return jax.jit(functools.partial(eng._server_tiles, dispatch=dispatch))


@given(
    st.sampled_from(["lcsm", "gla"]),
    st.integers(min_value=0, max_value=10**6),   # schedule/state seed
)
@settings(max_examples=10, deadline=None)
def test_tiles_batched_matches_reference(engine_name, seed):
    """One batched mask-select tile pass == one reference cond-ladder pass
    over randomized states, per-slot positions, origins, and live masks."""
    eng, bitwise = _ENGINES[engine_name]
    eng = eng()
    pv, origin, live = _rand_schedule(eng, seed)
    ref = _jit_tiles(engine_name, "reference")(
        eng.params, _rand_state(eng, seed), pv, origin, live)
    got = _jit_tiles(engine_name, "batched")(
        eng.params, _rand_state(eng, seed), pv, origin, live)
    _assert_states_equal(
        ref, got, bitwise=bitwise,
        msg=f"{engine_name} seed={seed} pv={np.asarray(pv)} "
            f"origin={np.asarray(origin)} live={np.asarray(live)}")


# --------------------------------------------- fused-chunk equivalence
@given(
    st.sampled_from(["lcsm", "gla"]),
    st.sampled_from([1, 2, 4]),                  # chunk size K
    st.integers(min_value=0, max_value=10**6),   # schedule/state seed
)
@settings(max_examples=8, deadline=None)
def test_server_chunk_batched_matches_reference(engine_name, K, seed):
    """``server_chunk`` (red passes + tiles + advances, K fused per-slot
    steps, jitted + donated) under both dispatch modes: bitwise-identical
    token streams, equal final states, identical rng advance."""
    eng, bitwise = _ENGINES[engine_name]
    eng = eng()
    # chunk starts inside the buffer so the red passes stay meaningful;
    # overshoot past the horizon still happens when p0 + K > Lbuf.
    pv, origin, live = _rand_schedule(eng, seed, overshoot=0)
    pv = jnp.minimum(pv, eng.Lbuf - 1)
    rng = jax.random.PRNGKey(seed)

    s_ref, t_ref, r_ref = eng.server_chunk(
        _rand_state(eng, seed), pv, origin, live, rng, K,
        dispatch="reference")
    s_bat, t_bat, r_bat = eng.server_chunk(
        _rand_state(eng, seed), pv, origin, live, rng, K,
        dispatch="batched")

    msg = (f"{engine_name} K={K} seed={seed} pv={np.asarray(pv)} "
           f"origin={np.asarray(origin)} live={np.asarray(live)}")
    np.testing.assert_array_equal(np.asarray(t_ref), np.asarray(t_bat),
                                  err_msg=f"tokens ({msg})")
    np.testing.assert_array_equal(np.asarray(r_ref), np.asarray(r_bat),
                                  err_msg=f"rng ({msg})")
    _assert_states_equal(s_ref, s_bat, bitwise=bitwise, msg=msg)


# -------------------------------------------------- server-level streams
def _hyena_cfg():
    from repro.configs import get_config
    return dataclasses.replace(get_config("hyena").smoke(),
                               name="hyena-dispatch", n_layers=2,
                               d_model=16, d_ff=32, vocab=64)


def _mixed_trace(vocab, pmax, gmax, n=10, seed=0):
    from repro.serving import Request
    rng = np.random.RandomState(seed)
    return [Request(uid=i,
                    prompt=rng.randint(0, vocab, (
                        int(rng.randint(1, pmax + 1)),)).astype(np.int32),
                    max_new=int(rng.randint(2, gmax + 1)))
            for i in range(n)]


def _serve(cfg, params, *, family, dispatch, chunk, mesh=None):
    from repro.serving import make_server
    srv = make_server(cfg, params, n_slots=4, prompt_max=4, gen_max=8,
                      **({"mesh": mesh} if family == "lcsm" else {}))
    srv.engine.server_dispatch = dispatch
    reqs = _mixed_trace(cfg.vocab, 4, 8)
    for r in reqs:
        srv.submit(r)
    srv.run(chunk=chunk)
    return {r.uid: tuple(r.out) for r in reqs}


@pytest.mark.parametrize("family", ["lcsm", "gla"])
@pytest.mark.parametrize("chunk", [None, 4])
def test_server_streams_batched_match_reference(family, chunk):
    """Whole mixed continuous-batching traces through LCSMServer /
    GenericServer: the batched dispatch emits exactly the reference
    ladder's greedy streams, per-step (step()'s tiles_step vs the per-
    (slot, side) host grouping) and chunked (server_chunk both modes)."""
    if family == "lcsm":
        from repro.models.hyena import HyenaLCSM
        cfg = _hyena_cfg()
        params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    else:
        from repro.configs import get_config
        from repro.models.gla import GLALM
        cfg = get_config("gla").smoke()
        params = GLALM(cfg).init(jax.random.PRNGKey(0))
    ref = _serve(cfg, params, family=family, dispatch="reference",
                 chunk=chunk)
    got = _serve(cfg, params, family=family, dispatch="batched", chunk=chunk)
    assert got == ref


@pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4): the "
           "forced-4-device CI leg pins batched == reference THROUGH a "
           "data-sharded server")
@pytest.mark.parametrize("chunk", [None, 4])
def test_sharded_server_streams_batched_match_reference(chunk):
    """The motivating configuration: under a 4-way data mesh (where every
    cond predicate was a cross-device sync) the batched dispatch must
    still emit exactly the reference ladder's streams."""
    from repro.launch.mesh import make_serving_mesh
    from repro.models.hyena import HyenaLCSM

    cfg = _hyena_cfg()
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    mesh = make_serving_mesh(data=4)
    ref = _serve(cfg, params, family="lcsm", dispatch="reference",
                 chunk=chunk, mesh=mesh)
    got = _serve(cfg, params, family="lcsm", dispatch="batched",
                 chunk=chunk, mesh=mesh)
    assert got == ref
