"""§4 generic framework (Algorithm 4) as library code: the fractal tile
schedule over a black-box P.1∧P.2 mixer must reproduce both the naive O(L²)
and the recurrent oracles exactly, under autoregressive feedback.

These tests drive the Python-loop ReferenceGenericEngine — the documented
slow reference.  The production jitted engine (GenericFlashEngine) is
covered by tests/test_generic_schedule.py and the GLA legs of
tests/test_differential.py / test_serving_continuous.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.generic import GatedLinearAttention, ReferenceGenericEngine
from repro.launch.analysis import cost_analysis_dict


def _mixer(D=6, dk=4, dv=5, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return GatedLinearAttention(
        wq=jax.random.normal(ks[0], (D, dk), jnp.float32),
        wk=jax.random.normal(ks[1], (D, dk), jnp.float32),
        wv=jax.random.normal(ks[2], (D, dv), jnp.float32),
        lam=0.95), D, dv


@pytest.mark.parametrize("L", [8, 16, 31, 32])
def test_algorithm4_matches_oracles(L):
    mixer, D, dv = _mixer()
    B = 2
    eng = ReferenceGenericEngine(mixer, batch=B, length=L)

    # teacher-forced inputs (fixed stream, ignores outputs)
    stream = jax.random.normal(jax.random.PRNGKey(9), (B, L, D), jnp.float32)

    def next_input(zs, z_i):
        return stream[:, len(zs)]

    ys, zs = eng.run(next_input, stream[:, 0])
    np.testing.assert_allclose(np.asarray(ys), np.asarray(stream), atol=1e-6)
    ref_naive = mixer.naive(stream)
    ref_rec = mixer.recurrent(stream)
    np.testing.assert_allclose(np.asarray(zs), np.asarray(ref_naive),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(zs), np.asarray(ref_rec),
                               rtol=1e-4, atol=1e-4)


def test_algorithm4_autoregressive_feedback():
    """With data-dependent inputs (y_{i+1} = f(z_i)) the schedule must still
    agree with the step-by-step recurrent evaluation — i.e. every z_i is
    complete BEFORE it is consumed."""
    mixer, D, dv = _mixer(D=5, dk=3, dv=5, seed=2)
    B, L = 1, 16
    W = jax.random.normal(jax.random.PRNGKey(4), (dv, D), jnp.float32) * 0.3
    y0 = jax.random.normal(jax.random.PRNGKey(5), (B, D), jnp.float32)

    def next_input(zs, z_i):
        return jnp.tanh(z_i @ W)

    eng = ReferenceGenericEngine(mixer, batch=B, length=L)
    ys, zs = eng.run(next_input, y0)

    # recurrent reference with identical feedback
    S = jnp.zeros((B, 3, dv), jnp.float32)
    y = y0
    for j in range(L):
        k, v = y @ mixer.wk, y @ mixer.wv
        S = mixer.lam * S + k[:, :, None] * v[:, None, :]
        z = mixer.read(S, y)
        np.testing.assert_allclose(np.asarray(zs[:, j]), np.asarray(z),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(ys[:, j]), np.asarray(y),
                                   rtol=1e-4, atol=1e-4)
        y = jnp.tanh(z @ W)


def test_range_alg_efficiency_contract():
    """T(U, U) must be o(U²): the decayed-sum range algorithm touches each
    input once and each output once (checked structurally via vmap trace —
    FLOP count linear in U)."""
    mixer, D, _ = _mixer()
    B, U = 1, 64
    y = jax.random.normal(jax.random.PRNGKey(0), (B, U, D), jnp.float32)
    offs = jnp.arange(1, U + 1)
    fn = jax.jit(lambda y: mixer.range_alg(y, 1, offs))
    flops = cost_analysis_dict(fn.lower(y).compile()).get("flops", 0)
    # linear-in-U budget: (U inputs + U outputs) × dk×dv × small-const
    assert flops <= 40 * U * mixer.dk * mixer.dv, flops
