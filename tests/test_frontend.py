"""Serving frontend: traffic scheduler, streaming delivery, prefix-state
cache, latency telemetry — plus the pow2 admission-prefill buckets.

The exactness bars:

* prefix-cache-hit streams are BITWISE identical to cold-prefill streams
  (LCSM and GLA, per-step and chunked) — a hit restores the exact rows
  the cold prefill would have written, and the server's rng schedule is
  split identically on both paths;
* restoring rows into a slot disturbs no other in-flight stream;
* the scheduler is deterministic on its virtual clock: same trace, same
  config -> same admissions, streams, and step-based metrics;
* admission prefill buckets prompt lengths to pow2, so the prefill jit
  cache holds O(log prompt_max) programs over a mixed-length workload.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.serving import Request, make_server
from repro.serving import generic_backend
from repro.serving.frontend import (PrefixCache, ServingMetrics,
                                    TrafficRequest, TrafficScheduler,
                                    poisson_trace, prefix_key)
from repro.serving.lcsm_backend import isolated_decode

PROMPT_MAX, GEN_MAX = 8, 16


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("hyena").smoke(), name="hyena-fe",
                              n_layers=4, d_model=32, d_ff=64, vocab=128)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def gla_setup():
    from repro.models.gla import GLALM

    cfg = dataclasses.replace(get_config("gla").smoke(), name="gla-fe",
                              n_layers=2, d_model=32, d_ff=64, vocab=128,
                              gla_dk=8, gla_dv=32)
    params = GLALM(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _server(cfg, params, n_slots=2, **kw):
    return make_server(cfg, params, n_slots=n_slots, prompt_max=PROMPT_MAX,
                       gen_max=GEN_MAX, **kw)


def _trace(vocab, n=7, hit_frac=0.6, seed=3, rate=0.7, gen_max=10):
    return poisson_trace(vocab, n, rate=rate, prompt_max=PROMPT_MAX,
                         gen_max=gen_max, hit_frac=hit_frac, seed=seed)


def _streams(trace):
    return {tr.req.uid: tuple(tr.req.out) for tr in trace}


# ------------------------------------------------ prefix-cache bitwise bars
@pytest.mark.parametrize("family,chunk", [
    ("lcsm", None), ("lcsm", 4), ("gla", None), ("gla", 4)])
def test_cache_hit_streams_bitwise_identical_to_cold(setup, gla_setup,
                                                     family, chunk):
    """Same trace served twice — prefix cache off vs on — must emit
    identical token streams for every request, per-step and chunked, in
    both engine families.  The cached path skips prefill entirely (hits
    observed below), so identity means the restored rows + replayed first
    token are bitwise the cold admission."""
    cfg, params = setup if family == "lcsm" else gla_setup

    def run(cache):
        sched = TrafficScheduler(
            _server(cfg, params), chunk=chunk,
            prefix_cache=PrefixCache() if cache else None)
        trace = _trace(cfg.vocab)
        rep = sched.run(trace)
        return _streams(trace), rep

    cold, _ = run(False)
    hot, rep = run(True)
    assert rep.cache["hits"] >= 1, "trace must actually exercise a hit"
    assert hot == cold


def test_cache_hit_matches_isolated_decode(setup):
    """Cache-hit streams must equal the per-request isolated batch-1
    reference — the same bar continuous batching is held to."""
    cfg, params = setup
    sched = TrafficScheduler(_server(cfg, params), prefix_cache=PrefixCache())
    trace = _trace(cfg.vocab)
    rep = sched.run(trace)
    assert rep.cache["hits"] >= 1
    for tr in trace:
        ref = isolated_decode(cfg, params, tr.req.prompt, len(tr.req.out),
                              prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
        assert tr.req.out == ref, f"req {tr.req.uid}"


def test_no_cross_slot_contamination_after_restore(setup):
    """A cache-hit restore into one slot must not perturb the other slots'
    in-flight streams: serve a trace where a shared-prompt request lands
    mid-flight next to unique-prompt requests, and check every stream
    against its isolated reference."""
    cfg, params = setup
    rng = np.random.RandomState(7)
    shared = rng.randint(0, cfg.vocab, (5,)).astype(np.int32)
    uniq = [rng.randint(0, cfg.vocab, (int(rng.randint(1, PROMPT_MAX + 1)),)
                        ).astype(np.int32) for _ in range(3)]
    trace = [
        TrafficRequest(Request(uid=0, prompt=shared, max_new=4), arrival=0),
        TrafficRequest(Request(uid=1, prompt=uniq[0], max_new=12), arrival=0),
        # arrives while uid=1 is mid-flight; restores into uid=0's old slot
        TrafficRequest(Request(uid=2, prompt=shared, max_new=9), arrival=1),
        TrafficRequest(Request(uid=3, prompt=uniq[1], max_new=6), arrival=2),
        TrafficRequest(Request(uid=4, prompt=uniq[2], max_new=8), arrival=3),
    ]
    sched = TrafficScheduler(_server(cfg, params), prefix_cache=PrefixCache())
    rep = sched.run(trace)
    assert rep.cache["hits"] == 1  # uid=2 restored from uid=0's snapshot
    for tr in trace:
        ref = isolated_decode(cfg, params, tr.req.prompt, len(tr.req.out),
                              prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
        assert tr.req.out == ref, f"req {tr.req.uid}"


def test_cache_eviction_under_tight_byte_budget(setup):
    """A budget sized for ~one entry must evict LRU: serving three distinct
    prompts A, B, A keeps at most one resident entry, counts evictions,
    and still produces correct streams (misses just prefill)."""
    cfg, params = setup
    rng = np.random.RandomState(1)
    pa = rng.randint(0, cfg.vocab, (4,)).astype(np.int32)
    pb = rng.randint(0, cfg.vocab, (6,)).astype(np.int32)
    srv = _server(cfg, params, n_slots=1)
    one_entry = sum(leaf.nbytes for leaf in jax.tree.leaves(
        srv.engine.init_state())) // srv.B  # bytes of one slot's rows
    cache = PrefixCache(byte_budget=int(one_entry * 1.5))
    trace = [TrafficRequest(Request(uid=i, prompt=p, max_new=3), arrival=i)
             for i, p in enumerate([pa, pb, pa])]
    sched = TrafficScheduler(srv, prefix_cache=cache)
    rep = sched.run(trace)
    assert rep.cache["evictions"] >= 1
    assert len(cache) == 1
    assert rep.cache["hits"] == 0  # A was evicted by B before its reuse
    for tr in trace:
        ref = isolated_decode(cfg, params, tr.req.prompt, len(tr.req.out),
                              prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
        assert tr.req.out == ref


def test_eviction_spills_to_host_tier_and_restores(setup):
    """With ``spill_budget`` set, the A, B, A pattern's eviction of A lands
    in the host spill tier instead of being dropped: the A reuse is a
    (spill) hit that skips prefill and still emits the bitwise cold
    stream.  Tier residency is part of the contract: the device tier
    stores exported rows AS-IS (live device arrays — no ``device_get`` on
    the admission path), only the forced spill materializes on host."""
    cfg, params = setup
    rng = np.random.RandomState(2)
    pa = rng.randint(0, cfg.vocab, (4,)).astype(np.int32)
    pb = rng.randint(0, cfg.vocab, (6,)).astype(np.int32)
    srv = _server(cfg, params, n_slots=1)
    one_entry = sum(leaf.nbytes for leaf in jax.tree.leaves(
        srv.engine.init_state())) // srv.B
    cache = PrefixCache(byte_budget=int(one_entry * 1.5),
                        spill_budget=4 * one_entry)
    trace = [TrafficRequest(Request(uid=i, prompt=p, max_new=3), arrival=i)
             for i, p in enumerate([pa, pb, pa])]
    sched = TrafficScheduler(srv, prefix_cache=cache)
    rep = sched.run(trace)
    assert rep.cache["evictions"] >= 1 and rep.cache["spills"] >= 1
    assert rep.cache["spill_hits"] >= 1 and rep.cache["hits"] >= 1
    assert len(cache) == 1  # device tier: B only; A lives in the spill tier
    for e in cache._entries.values():
        assert all(isinstance(leaf, jax.Array)
                   for leaf in jax.tree.leaves(e.rows))
    for e in cache._spill.values():
        assert all(isinstance(leaf, np.ndarray)
                   for leaf in jax.tree.leaves(e.rows))
    for tr in trace:
        ref = isolated_decode(cfg, params, tr.req.prompt, len(tr.req.out),
                              prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
        assert tr.req.out == ref


def test_oversized_entry_not_stored():
    cache = PrefixCache(byte_budget=8)
    ok = cache.insert(prefix_key([1, 2], 16), {"x": np.zeros(64)}, 0, 2)
    assert not ok and len(cache) == 0


# ----------------------------------------------- scheduler traffic behavior
def test_scheduler_deterministic_virtual_clock(setup):
    """Two runs of the same trace: identical streams AND identical
    step-based metrics (wall-clock fields may differ)."""
    cfg, params = setup

    def run():
        sched = TrafficScheduler(_server(cfg, params),
                                 prefix_cache=PrefixCache())
        trace = _trace(cfg.vocab, seed=11)
        rep = sched.run(trace)
        return _streams(trace), rep.metrics

    s1, m1 = run()
    s2, m2 = run()
    assert s1 == s2
    assert m1["ttft_steps"] == m2["ttft_steps"]
    assert m1["queue_depth"] == m2["queue_depth"]
    assert m1["slot_occupancy"] == m2["slot_occupancy"]
    assert m1["steps"] == m2["steps"]


def test_streaming_delivery_tokens_and_callbacks(setup):
    """serve() yields every token exactly once, in order, with monotone
    delivery steps; on_token callbacks observe the same stream; chunked
    delivery arrives in bursts but concatenates to the same stream."""
    cfg, params = setup
    got: dict[int, list[int]] = {}
    trace = _trace(cfg.vocab, n=5, seed=4)
    for tr in trace:
        tr.on_token = (lambda uid: lambda tok, i: got.setdefault(
            uid, []).append(tok))(tr.req.uid)
    sched = TrafficScheduler(_server(cfg, params))
    events = list(sched.serve(trace))
    by_uid: dict[int, list] = {}
    for ev in events:
        by_uid.setdefault(ev.uid, []).append(ev)
    for tr in trace:
        evs = by_uid[tr.req.uid]
        assert [e.token for e in evs] == tr.req.out == got[tr.req.uid]
        assert [e.index for e in evs] == list(range(len(tr.req.out)))
        assert all(a.step <= b.step for a, b in zip(evs, evs[1:]))
        assert [e.done for e in evs] == [False] * (len(evs) - 1) + [True]
    # chunked: same streams, delivered in >1-token bursts at chunk steps
    trace2 = _trace(cfg.vocab, n=5, seed=4)
    events2 = list(TrafficScheduler(
        _server(cfg, params), chunk=4).serve(trace2))
    assert _streams(trace2) == _streams(trace)
    steps_per_uid = {}
    for ev in events2:
        steps_per_uid.setdefault(ev.uid, []).append(ev.step)
    assert any(len(set(s)) < len(s) for s in steps_per_uid.values()), \
        "chunked delivery should batch several tokens per step"


def test_policy_spf_admits_shortest_prompt_first(setup):
    """Simultaneous arrivals against one slot: FCFS admits in arrival
    order, SPF admits the shortest prompt first — visible in admission
    steps and unchanged per-request streams."""
    cfg, params = setup
    rng = np.random.RandomState(5)
    long_p = rng.randint(0, cfg.vocab, (8,)).astype(np.int32)
    short_p = rng.randint(0, cfg.vocab, (2,)).astype(np.int32)

    def admit_order(policy):
        trace = [
            TrafficRequest(Request(uid=0, prompt=long_p, max_new=4),
                           arrival=0),
            TrafficRequest(Request(uid=1, prompt=short_p, max_new=4),
                           arrival=0),
        ]
        sched = TrafficScheduler(_server(cfg, params, n_slots=1),
                                 policy=policy)
        rep = sched.run(trace)
        per = {r["uid"]: r for r in rep.metrics["per_request"]}
        order = sorted(per, key=lambda u: per[u]["admit_step"])
        for tr in trace:  # streams themselves must not depend on policy
            ref = isolated_decode(cfg, params, tr.req.prompt, len(tr.req.out),
                                  prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
            assert tr.req.out == ref
        return order

    assert admit_order("fcfs") == [0, 1]
    assert admit_order("spf") == [1, 0]


def test_queue_limit_backpressure(setup):
    """queue_limit=1 against a 1-slot server: a burst of 4 simultaneous
    arrivals fills the slot (1) and the queue (1); the 2 overflow requests
    are rejected (no tokens), the rest are served to completion.  An
    arrival may always take a free slot — the bound applies to what must
    WAIT — so even queue_limit=0 serves exactly the slot count."""
    cfg, params = setup
    rng = np.random.RandomState(6)

    def burst():
        return [TrafficRequest(
            Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab, (3,)).astype(np.int32),
                    max_new=6), arrival=0.0) for i in range(4)]

    trace = burst()
    rep = TrafficScheduler(_server(cfg, params, n_slots=1),
                           queue_limit=1).run(trace)
    assert rep.metrics["requests"]["rejected"] == 2
    assert rep.metrics["requests"]["completed"] == 2
    assert len(rep.rejected_uids) == 2
    for tr in trace:
        if tr.rejected:
            assert tr.req.out == []
        else:
            assert len(tr.req.out) == tr.req.max_new

    trace0 = burst()
    rep0 = TrafficScheduler(_server(cfg, params, n_slots=1),
                            queue_limit=0).run(trace0)
    assert rep0.metrics["requests"]["completed"] == 1  # serve-or-reject-now
    assert rep0.metrics["requests"]["rejected"] == 3


def test_metrics_snapshot_structure(setup):
    cfg, params = setup
    met = ServingMetrics()
    sched = TrafficScheduler(_server(cfg, params), metrics=met,
                             prefix_cache=PrefixCache())
    rep = sched.run(_trace(cfg.vocab, n=4, seed=9))
    m = rep.metrics
    assert set(m) >= {"requests", "ttft_s", "ttft_steps", "token_gap_s",
                      "throughput", "queue_depth", "slot_occupancy", "steps"}
    r = m["requests"]
    assert r["submitted"] == 4 and r["completed"] == 4
    assert r["cache_hits"] + r["cache_misses"] == r["admitted"]
    assert m["throughput"]["tokens"] == sum(
        t["n_tokens"] for t in m["per_request"])
    assert m["throughput"]["tok_s"] > 0
    assert m["ttft_s"]["n"] == 4 and m["ttft_s"]["mean"] > 0
    assert 0 < m["slot_occupancy"]["mean"] <= 1


def test_frontend_works_with_transformer_backend():
    """The scheduler runs the transformer ServingEngine too (no prefix
    cache there — growing KV rows aren't sliceable snapshots)."""
    import jax.numpy as jnp

    from repro.models.lm import LM
    from repro.serving import ServingEngine

    cfg = get_config("qwen2.5-3b").smoke()
    params = LM(cfg).init(jax.random.PRNGKey(0))
    srv = make_server(cfg, params, n_slots=2, max_seq=32,
                      cache_dtype=jnp.float32)
    assert isinstance(srv, ServingEngine)
    rng = np.random.RandomState(0)
    trace = [TrafficRequest(
        Request(uid=i, prompt=rng.randint(0, cfg.vocab, (3,)).astype(np.int32),
                max_new=4), arrival=float(i)) for i in range(3)]
    rep = TrafficScheduler(srv).run(trace)
    assert all(len(tr.req.out) == 4 for tr in trace)
    assert rep.metrics["requests"]["completed"] == 3
    with pytest.raises(AssertionError):
        TrafficScheduler(srv, prefix_cache=PrefixCache())
    # done-at-admission honors max_new on the submit()/run() path too
    # (regression: the seed _admit skipped the check and emitted 2 tokens)
    r1 = Request(uid=9, prompt=rng.randint(0, cfg.vocab, (3,)
                                           ).astype(np.int32), max_new=1)
    srv.submit(r1)
    done = srv.run()
    assert r1 in done and r1.done and len(r1.out) == 1


def test_make_server_builds_frontend(setup):
    cfg, params = setup
    sched = _server(cfg, params, frontend=dict(policy="spf",
                                               prefix_cache=True))
    assert isinstance(sched, TrafficScheduler)
    assert sched.policy == "spf" and sched.cache is not None
    assert sched.server.B == 2


# ----------------------------------------- engine-level export/import rows
@pytest.mark.parametrize("family", ["lcsm", "gla"])
def test_export_import_roundtrip_across_servers(setup, gla_setup, family):
    """Rows exported from one server's slot, imported into a DIFFERENT
    slot of a fresh server, continue the stream exactly (the snapshot is
    the whole per-slot inference state)."""
    cfg, params = setup if family == "lcsm" else gla_setup
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, cfg.vocab, (5,)).astype(np.int32)

    srv1 = _server(cfg, params, n_slots=2)
    fin: list[Request] = []
    r1 = Request(uid=0, prompt=prompt, max_new=GEN_MAX)
    slot = srv1.admit(r1, finished=fin)
    rows = srv1.export_slot(slot)
    rows = jax.device_get(rows)  # survive srv1's donations

    srv1.run()  # finish stream 1 (donates/overwrites srv1 state freely)

    srv2 = _server(cfg, params, n_slots=3)
    # occupy slot 0 with an unrelated request so the restore lands in a
    # genuinely different slot index than the snapshot came from
    other = Request(uid=9, prompt=rng.randint(0, cfg.vocab, (3,)
                                              ).astype(np.int32),
                    max_new=GEN_MAX)
    assert srv2.admit(other) == 0
    r2 = Request(uid=1, prompt=prompt, max_new=GEN_MAX)
    slot2 = srv2.admit(r2, rows=rows, first_token=r1.out[0])
    assert slot2 == 1 != slot
    srv2.run()
    assert r2.out == r1.out


def test_admit_done_at_admission_keeps_slot_free(setup):
    cfg, params = setup
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab, (4,)).astype(np.int32)
    srv = _server(cfg, params, n_slots=1)
    fin: list[Request] = []
    r = Request(uid=0, prompt=prompt, max_new=1)
    slot = srv.admit(r, finished=fin)
    assert slot == 0 and r.done and fin == [r]
    assert srv.slots[0] is None  # slot still free, rows still exportable
    assert srv.export_slot(0) is not None


# ------------------------------------------------- pow2 prefill bucketing
@pytest.mark.parametrize("family", ["lcsm", "gla"])
def test_admission_prefill_jit_cache_is_log_bounded(setup, gla_setup, family):
    """Admitting every prompt length 1..PROMPT_MAX must compile at most
    log2(ceil_pow2(PROMPT_MAX)) + 1 prefill programs (the pow2 buckets),
    not PROMPT_MAX of them — and the streams must still match their
    isolated references."""
    cfg, params = setup if family == "lcsm" else gla_setup
    srv = _server(cfg, params, n_slots=2)
    iso = (isolated_decode if family == "lcsm"
           else generic_backend.isolated_decode)
    reqs = []
    rng = np.random.RandomState(0)
    for P in range(1, PROMPT_MAX + 1):
        reqs.append(Request(
            uid=P, prompt=rng.randint(0, cfg.vocab, (P,)).astype(np.int32),
            max_new=3))
        srv.submit(reqs[-1])
    srv.run()
    bound = PROMPT_MAX.bit_length()  # log2(ceil_pow2(8)) + 1 = 4
    assert srv.engine._jit_prefill_slot._cache_size() <= bound, (
        srv.engine._jit_prefill_slot._cache_size(), bound)
    for r in reqs:
        ref = iso(cfg, params, r.prompt, len(r.out),
                  prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
        assert r.out == ref, f"P={r.uid}"
