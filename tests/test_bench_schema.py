"""Every committed experiments/bench/BENCH_*.json follows ONE schema:

    {"bench": str, "machine": {...}, "config": {...}, "series": [cell, ...]}

(benchmarks/common.write_bench_json).  bench_serving and bench_decode used
to emit differently-shaped records; this pins the normalization so the
committed numbers stay machine-readable by one loader.
"""

import glob
import json
import os

import pytest

BENCH_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "..", "experiments", "bench")


def _bench_files():
    return sorted(glob.glob(os.path.join(BENCH_DIR, "BENCH_*.json")))


def test_committed_bench_records_exist():
    names = {os.path.basename(p) for p in _bench_files()}
    assert {"BENCH_decode.json", "BENCH_serving.json",
            "BENCH_sharded.json", "BENCH_generic.json",
            "BENCH_traffic.json"} <= names, names


@pytest.mark.parametrize("path", _bench_files(), ids=os.path.basename)
def test_bench_record_schema(path):
    with open(path) as f:
        rec = json.load(f)
    assert set(rec) == {"bench", "machine", "config", "series"}, set(rec)
    assert isinstance(rec["bench"], str) and rec["bench"]

    machine = rec["machine"]
    for key in ("backend", "device_count", "device_kind", "python", "jax"):
        assert key in machine, f"machine missing {key!r}"
    assert machine["device_count"] >= 1

    assert isinstance(rec["config"], dict) and rec["config"]

    series = rec["series"]
    assert isinstance(series, list) and series
    for cell in series:
        assert isinstance(cell, dict)
        assert isinstance(cell.get("tokens"), int) and cell["tokens"] > 0
        assert isinstance(cell.get("seconds"), (int, float))
        assert isinstance(cell.get("tok_s"), (int, float)) and cell["tok_s"] > 0


def test_generic_bench_covers_both_modes():
    """Acceptance: BENCH_generic.json reports flash (chunk-K sweep) AND the
    recurrent oracle, measured on verified-identical greedy streams."""
    path = os.path.join(BENCH_DIR, "BENCH_generic.json")
    with open(path) as f:
        rec = json.load(f)
    modes = {cell["mode"] for cell in rec["series"]}
    assert modes == {"flash", "recurrent"}, modes
    assert len({c["chunk_K"] for c in rec["series"]
                if c["mode"] == "flash"}) >= 2
    assert rec["config"]["streams_identical_across_modes"] is True


def test_traffic_bench_covers_cache_sweep_with_telemetry():
    """Acceptance: BENCH_traffic.json reports an open-loop streamed run —
    latency telemetry per cell (TTFT, queue depth, occupancy) over >= 2
    prefix-cache hit fractions with cache on AND off, measured on streams
    verified identical with and without the cache."""
    path = os.path.join(BENCH_DIR, "BENCH_traffic.json")
    with open(path) as f:
        rec = json.load(f)
    assert rec["config"]["streams_identical_with_cache"] is True
    assert len(rec["config"]["hit_fracs"]) >= 2
    assert {c["cache"] for c in rec["series"]} == {True, False}
    assert len({c["hit_frac"] for c in rec["series"]}) >= 2
    for cell in rec["series"]:
        for key in ("ttft_mean_s", "ttft_p95_s", "token_gap_mean_s",
                    "queue_depth_mean", "slot_occupancy_mean", "cache_hits"):
            assert key in cell, f"series cell missing {key!r}"
        assert cell["ttft_mean_s"] > 0
    # a cache-on cell at a nonzero hit fraction must actually hit
    assert any(c["cache"] and c["hit_frac"] > 0 and c["cache_hits"] > 0
               for c in rec["series"])


def test_sharded_bench_covers_multiple_device_counts():
    """Acceptance: BENCH_sharded.json shows tok/s for >= 2 device counts,
    measured with streams verified identical across meshes, across the
    replica layout, and vs the retired cond-ladder reference dispatch —
    and the committed weak-scaling sweep is monotone non-decreasing in
    device count (prefill amortization over the shared device-resident
    prefix cache must actually pay)."""
    path = os.path.join(BENCH_DIR, "BENCH_sharded.json")
    with open(path) as f:
        rec = json.load(f)
    counts = {cell["devices"] for cell in rec["series"]}
    assert len(counts) >= 2, counts
    for key in ("streams_identical_across_meshes",
                "streams_identical_across_replicas",
                "streams_identical_vs_reference_dispatch"):
        assert rec["config"][key] is True, key
    sweep = sorted(rec["series"], key=lambda c: c["devices"])
    rates = [c["tok_s"] for c in sweep]
    assert all(a <= b for a, b in zip(rates, rates[1:])), (
        f"sharded sweep tok/s not monotone non-decreasing: {rates}")
    # the scale-out mechanism must be visible: every multi-device cell
    # serves replicated traffic from the shared cache
    assert all(c["cache_hits"] > 0 for c in sweep if c["devices"] > 1)
