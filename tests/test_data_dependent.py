"""Appendix B (Algorithm 5): relaxed multiplication with BOTH sequences
revealed online — coverage, causality and exactness."""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "examples"))

from data_dependent_filters import flash_data_dependent  # noqa: E402


def test_exact_vs_naive_online():
    rng = np.random.RandomState(3)
    L = 128
    by, br = rng.randn(L), rng.randn(L)

    def y_fn(i, z):
        return by[i] + (0.05 * z[-1] if len(z) else 0.0)

    def rho_fn(i, z):
        return br[i] + (0.03 * np.tanh(z[-1]) if len(z) else 0.0)

    got = flash_data_dependent(y_fn, rho_fn, L)
    y = np.zeros(L); r = np.zeros(L); z = np.zeros(L)
    for t in range(L):
        y[t] = y_fn(t, z[:t])
        r[t] = rho_fn(t, z[:t])
        z[t] = sum(y[i] * r[t - i] for i in range(t + 1))
    np.testing.assert_allclose(got, z, rtol=1e-10, atol=1e-10)


def test_reveal_order_is_respected():
    """y_fn/rho_fn must never be asked for index i before z_{i-1} exists."""
    calls = []

    def y_fn(i, z):
        calls.append(("y", i, len(z)))
        assert len(z) == i, f"y_{i} requested with only {len(z)} outputs"
        return 1.0 / (i + 1)

    def rho_fn(i, z):
        assert len(z) == i
        return 0.5 ** i

    flash_data_dependent(y_fn, rho_fn, 64)
    assert [c[1] for c in calls] == list(range(64))  # strictly in order
