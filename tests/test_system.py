"""End-to-end system behaviour: the paper's exactness claim at the full
serving stack level + flash-vs-naive token-stream equality with prompts,
across-layer parallel batching, and generic-framework instantiation."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.serving import LCSMServer


@pytest.fixture(scope="module")
def hyena_setup():
    cfg = dataclasses.replace(get_config("hyena").smoke(), name="hyena-sys",
                              n_layers=4, d_model=32, d_ff=64, vocab=128)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def test_flash_lazy_eager_emit_identical_tokens(hyena_setup):
    cfg, params = hyena_setup
    outs = {}
    for strategy in ("flash", "lazy", "eager"):
        srv = LCSMServer(cfg, params, batch=2, gen_max=24, strategy=strategy)
        outs[strategy] = srv.generate(None, 24)
    np.testing.assert_array_equal(outs["flash"], outs["lazy"])
    np.testing.assert_array_equal(outs["flash"], outs["eager"])


def test_flash_with_prompt_matches_lazy(hyena_setup):
    cfg, params = hyena_setup
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (2, 5)).astype(np.int32)
    a = LCSMServer(cfg, params, batch=2, gen_max=16, prompt_max=5,
                   strategy="flash").generate(prompts, 16)
    b = LCSMServer(cfg, params, batch=2, gen_max=16, prompt_max=5,
                   strategy="lazy").generate(prompts, 16)
    np.testing.assert_array_equal(a, b)


def test_tau_impl_choice_does_not_change_tokens(hyena_setup):
    cfg, params = hyena_setup
    ref = None
    for tau_impl in ("direct", "fft", "hybrid"):
        srv = LCSMServer(cfg, params, batch=1, gen_max=16, tau_impl=tau_impl)
        out = srv.generate(None, 16)
        if ref is None:
            ref = out
        else:
            np.testing.assert_array_equal(ref, out)


def test_pallas_tau_in_engine(hyena_setup):
    cfg, params = hyena_setup
    ref = LCSMServer(cfg, params, batch=1, gen_max=8).generate(None, 8)
    srv = LCSMServer(cfg, params, batch=1, gen_max=8, tau_impl="pallas")
    out = srv.generate(None, 8)
    np.testing.assert_array_equal(ref, out)


# --------------------------------------------------- generic framework (§4)
def test_generic_framework_linear_attention():
    """'and Beyond': instantiate Algorithm 4 for a gated linear-attention
    mixer (P.1: cont(y,i,j) = decay^(j-i)·(k_i·q_j)·v_i, agg = +; P.2 holds
    for fixed q since cont(·,i,·) is independent of y_{i+1..}).  The fractal
    tile schedule must reproduce the naive O(L²) evaluation exactly."""
    from repro.core.tiling import tile_schedule

    rng = np.random.RandomState(1)
    L, D = 64, 4
    decay = 0.97
    k = rng.randn(L, D).astype(np.float32)
    v = rng.randn(L, D).astype(np.float32)
    q = rng.randn(L, D).astype(np.float32)

    def cont(i, j):  # contribution of position i to output j (1-based)
        w = decay ** (j - i)
        return w * (k[i - 1] @ q[j - 1]) * v[i - 1]

    naive = np.stack([sum(cont(i, j) for i in range(1, j + 1))
                      for j in range(1, L + 1)])

    b = np.zeros((L, D), np.float32)
    for j in range(1, L + 1):
        b[j - 1] += cont(j, j)  # red cells
    for t in tile_schedule(L):
        for j in range(t.out_lo, t.out_hi + 1):
            for i in range(t.in_lo, t.in_hi + 1):
                b[j - 1] += cont(i, j)
    np.testing.assert_allclose(b, naive, rtol=1e-4, atol=1e-4)


def test_half_activation_memory_appendix_d():
    """Appendix D: after iteration L/2 completes, no remaining tile reads
    activations at positions <= L/2."""
    from repro.core.tiling import tile_schedule

    L = 128
    for t in tile_schedule(L):
        if t.step > L // 2:
            assert t.in_lo > L // 2, (
                f"tile at step {t.step} reads position {t.in_lo} <= L/2")


def test_multihead_hyena_shared_filters():
    """Multi-head Hyena (shared filters per group, §2.3) — exactness of the
    flash decode must be unaffected by filter sharing."""
    import dataclasses

    cfg = dataclasses.replace(get_config("hyena").smoke(), name="hyena-mh",
                              n_layers=4, d_model=32, d_ff=64, vocab=128,
                              hyena_filter_groups=4)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(1))
    a = LCSMServer(cfg, params, batch=2, gen_max=16, strategy="flash").generate(None, 16)
    b = LCSMServer(cfg, params, batch=2, gen_max=16, strategy="lazy").generate(None, 16)
    np.testing.assert_array_equal(a, b)
    # filters really are shared within groups
    from repro.models.hyena import materialize_filters
    rho = materialize_filters(params["ops"][0]["filter"], 16, cfg.d_model,
                              pos_dim=cfg.filter_pos_dim)
    g = cfg.d_model // cfg.hyena_filter_groups
    np.testing.assert_array_equal(np.asarray(rho[0, :, 0]), np.asarray(rho[0, :, g - 1]))
