"""Launch-layer unit tests: sharding rules, HLO collective parser, case
builder (host-mesh), analytic FLOPs."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as sh
from repro.launch.analysis import (collective_bytes, cost_analysis_dict,
                                   count_params, model_flops_for)
from repro.launch.mesh import make_host_mesh


def test_param_spec_rules():
    mesh = make_host_mesh()  # sizes 1 → every axis divides; specs keep names
    # fabricate shapes that the production mesh divides
    spec = sh.param_spec_for_path("['emb']", 2, (32064, 4096), mesh)
    assert spec == P(None, "model")
    spec = sh.param_spec_for_path("['stack0'][0]['attn']['wq']['w']", 3,
                                  (32, 4096, 4096), mesh)
    assert spec == P(None, "data", "model")
    spec = sh.param_spec_for_path("['stack0'][0]['attn']['wo']['w']", 3,
                                  (32, 4096, 4096), mesh)
    assert spec == P(None, "model", "data")
    spec = sh.param_spec_for_path("['stack0'][0]['moe']['w1']", 4,
                                  (32, 16, 4096, 6400), mesh)
    assert spec == P(None, "model", "data", None)
    # norms replicated
    assert sh.param_spec_for_path("['norm_f']['w']", 1, (4096,), mesh) == P()
    # biases replicated (no rule matches ['b'] paths)
    assert sh.param_spec_for_path("['attn']['wq']['b']", 2, (32, 4096), mesh) == P()


def test_divisibility_guard():
    mesh = jax.make_mesh((1, len(jax.devices())), ("data", "model"))
    # whisper vocab 51865 is not divisible by anything > 1 — emb spec must
    # drop the axis rather than error (here model=1 so it is kept).
    spec = sh.param_spec_for_path("['emb']", 2, (51865, 384), mesh)
    assert spec in (P(None, "model"), P(None, None))


def test_collective_bytes_parser():
    hlo = """
  %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[8,256]{1,0} all-gather(%y), dimensions={1}
  %tup = (f32[4,4]{1,0}, f32[2,2]{1,0}) all-to-all(%a, %b)
  %ard = f32[16,1024]{1,0} all-reduce-done(%w)
  %nothing = f32[2,2]{1,0} add(%p, %q)
"""
    total, per_op = collective_bytes(hlo)
    assert per_op["all-reduce"] == 16 * 1024 * 4
    assert per_op["all-gather"] == 8 * 256 * 2
    assert per_op["all-to-all"] == 16 * 4 + 4 * 4
    assert total == sum(per_op.values())


def test_count_params_sane():
    # qwen2.5-3b ~ 3.1B total params (with 0.3B embeddings x1 tied)
    total, active = count_params(get_config("qwen2.5-3b"))
    assert 2.5e9 < total < 4e9
    assert total == active
    # phi3.5-moe: 42B total, 6.6B active
    total, active = count_params(get_config("phi3.5-moe-42b-a6.6b"))
    assert 3.4e10 < total < 5.2e10, total
    assert 5e9 < active < 9e9, active
    # deepseek-v3: ~671B total, ~37B active
    total, active = count_params(get_config("deepseek-v3-671b"))
    assert 5.5e11 < total < 7.5e11, total
    assert 2.4e10 < active < 5e10, active


def test_model_flops_train_formula():
    cfg = get_config("qwen2.5-3b")
    f = model_flops_for(cfg, "train_4k")
    _, active = count_params(cfg)
    assert f == pytest.approx(6.0 * active * 4096 * 256)


@pytest.mark.parametrize("name", ["qwen2.5-3b", "falcon-mamba-7b", "hyena"])
def test_case_builder_host_mesh_lowers(name):
    """Smoke-config cases lower+compile on the 1-device host mesh — the
    same builder path the 512-device dry-run uses."""
    from repro.launch.specs import Skip, build_case

    cfg = get_config(name).smoke()
    # shrink the shape table for CPU: monkeypatch via a tiny local copy
    mesh = make_host_mesh()
    case = build_case(cfg, "decode_32k", mesh)
    if isinstance(case, Skip):
        pytest.skip(case.reason)
    jitted = jax.jit(case.step_fn, in_shardings=case.in_shardings,
                     out_shardings=case.out_shardings,
                     donate_argnums=case.donate)
    with mesh:
        compiled = jitted.lower(*case.args).compile()
    assert cost_analysis_dict(compiled).get("flops", 0) > 0
