"""The mesh-lowered Flash-Inference steps (launch/lcsm_steps.py) must emit
exactly the same tokens as the host FlashEngine (core/engine.py) — two
implementations of Algorithms 2/3 over different buffer layouts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.tiling import largest_pow2_divisor
from repro.launch import lcsm_steps
from repro.models.hyena import HyenaLCSM
from repro.serving import LCSMServer


def test_lowered_steps_match_engine():
    cfg = dataclasses.replace(get_config("hyena").smoke(), name="hyena-steps",
                              n_layers=4, d_model=32, d_ff=64, vocab=64)
    model = HyenaLCSM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, n = 2, 24
    w = cfg.short_conv_k - 1

    # reference: host engine
    ref = LCSMServer(cfg, params, batch=B, gen_max=n).generate(None, n)

    # lowered steps, offset by w so window slices never clamp (history
    # before the seed position is zero — same as the engine's zero fill).
    # The implicit filters are LENGTH-NORMALIZED, so they must be
    # materialized at the engine's Lbuf (ceil_pow2(n)) and zero-extended.
    from repro.core.engine import ceil_pow2

    Lbuf_eng = ceil_pow2(n)
    Lbuf = Lbuf_eng + w + 1
    bufs = lcsm_steps.materialize_buffers(cfg, params, B, Lbuf)
    rho = jnp.stack(model.filters(params, Lbuf_eng))
    rho = jnp.pad(rho, ((0, 0), (0, Lbuf - Lbuf_eng), (0, 0)))
    bufs = dict(bufs, rho=rho, rho0=rho[:, 0])
    bufs = lcsm_steps.seed_first_token(
        cfg, params, bufs, jnp.zeros((B,), jnp.int32), pos=w)
    red = jax.jit(lcsm_steps.make_red_step(cfg))
    grays = {}
    streams, b = bufs["streams"], bufs["b"]
    toks = []
    for step in range(n):
        pos = w + step
        streams, b, tok = red(params, streams, b, pos, bufs["rho0"])
        toks.append(np.asarray(tok))
        U = largest_pow2_divisor(step + 1)
        if (pos - w) + U < Lbuf_eng:  # same tile-drop rule as the engine
            if U not in grays:
                grays[U] = jax.jit(lcsm_steps.make_gray_step(cfg, U))
            b = grays[U](streams, b, pos, bufs["rho"])
    got = np.stack(toks, axis=1)
    np.testing.assert_array_equal(got, ref)


def test_appendix_d_compaction_preserves_generation():
    """Run the lowered steps with a mid-stream Appendix-D compaction and
    check the token stream is unchanged — the mechanical proof that the
    half-activation-storage scheme is sound."""
    cfg = dataclasses.replace(get_config("hyena").smoke(), name="hyena-appd",
                              n_layers=4, d_model=32, d_ff=64, vocab=64)
    model = HyenaLCSM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, n = 1, 16
    w = cfg.short_conv_k - 1
    from repro.core.engine import ceil_pow2

    Lbuf_eng = ceil_pow2(n)
    Lbuf = Lbuf_eng + w + 1
    rho_full = jnp.stack(model.filters(params, Lbuf_eng))
    rho = jnp.pad(rho_full, ((0, 0), (0, Lbuf - Lbuf_eng), (0, 0)))

    def run(compact_at=None):
        bufs = lcsm_steps.materialize_buffers(cfg, params, B, Lbuf)
        bufs = dict(bufs, rho=rho, rho0=rho[:, 0])
        bufs = lcsm_steps.seed_first_token(
            cfg, params, bufs, jnp.zeros((B,), jnp.int32), pos=w)
        red = jax.jit(lcsm_steps.make_red_step(cfg))
        grays = {}
        streams, b = bufs["streams"], bufs["b"]
        shift = 0
        toks = []
        for step in range(n):
            pos = w + step - shift
            streams, b, tok = red(params, streams, b, pos, bufs["rho0"])
            toks.append(int(np.asarray(tok)[0]))
            U = largest_pow2_divisor(step + 1)
            if (w + step - w) + U < Lbuf_eng:
                if U not in grays:
                    grays[U] = jax.jit(lcsm_steps.make_gray_step(cfg, U))
                b = grays[U](streams, b, pos, bufs["rho"])
            if compact_at is not None and step + 1 == compact_at:
                # App-D shift: drop the fully-consumed prefix.  Valid as
                # soon as no future tile reads below `drop` — tiles at
                # step s read [s-U+1, s] with U | s, so dropping up to
                # the last power-of-two boundary is safe.
                drop = (step + 1) // 2
                c = lcsm_steps.compact_buffers(
                    dict(bufs, streams=streams, b=b), drop)
                streams, b = c["streams"], c["b"]
                shift += drop
        return toks

    base = run(None)
    # compact right after the step-8 tile (steps 9.. read >= position 8)
    assert base == run(compact_at=8)
