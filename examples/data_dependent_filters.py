"""Appendix B demo: Flash Inference with DATA-DEPENDENT filters
(Algorithm 5 — van der Hoeven's parallelogram tiling).

When the filter rho is itself a causal function of the data, the
rectangle tiling of Algorithm 2 cannot run (it would need rho prefixes
that are not yet revealed).  Algorithm 5 uses untruncated convolutions
(parallelogram tiles) and order-2U FFTs, at 2× the FLOPs of the
data-independent path.  This script implements the SISO case and checks
it against the naive online evaluation.

    PYTHONPATH=src python examples/data_dependent_filters.py
"""

import numpy as np


def conv_full(a, b):
    return np.convolve(a, b)


def flash_data_dependent(y_fn, rho_fn, L):
    """Algorithm 5 / van der Hoeven relaxed multiplication (SISO):
    y_fn(i, z) and rho_fn(i, z) reveal y_i / rho_i causally given the
    finalized outputs z[0..i-1] (0-based here).

    Tiling: after revealing index n, for EVERY p = 2^k dividing n+1:
      m = (n+1)/p == 2 → the diagonal square y[p:2p] ∗ rho[p:2p] (once);
      m ≥ 3          → the parallelogram pair  y[p:2p] ∗ rho[n+1-p:n+1]
                        and rho[p:2p] ∗ y[n+1-p:n+1].
    Every cell (a, b) with a, b ≥ 1 lands in exactly one tile (k fixed by
    a, block index by b), inputs are always already revealed, and outputs
    land strictly after n — so z_t is complete when returned.  Total cost
    Σ_k (L/2^k)·O(2^k log 2^k) = O(L log² L) — 2× the data-independent
    rectangle tiling of Algorithm 2, as the paper states.

    Returns z with z_t = Σ_{i<=t} y_i·rho_{t-i}, never reading an entry
    before its reveal time.
    """
    y = np.zeros(L)
    rho = np.zeros(L)
    z = np.zeros(4 * L + 4)  # slack for eager pushes past the horizon
    y[0] = y_fn(0, z[:0])
    rho[0] = rho_fn(0, z[:0])
    z[0] = y[0] * rho[0]
    for n in range(1, L):
        y[n] = y_fn(n, z[:n])
        rho[n] = rho_fn(n, z[:n])
        # anti-diagonal contributions of the fresh entries (row/col 0)
        z[n] += y[n] * rho[0] + y[0] * rho[n]
        p = 1
        while (n + 1) % p == 0 and 2 * p <= n + 1:
            m = (n + 1) // p
            if m == 2:
                z[2 * p : 4 * p - 1] += conv_full(y[p : 2 * p], rho[p : 2 * p])
            else:
                z[n + 1 : n + 2 * p] += conv_full(y[p : 2 * p], rho[n + 1 - p : n + 1])
                z[n + 1 : n + 2 * p] += conv_full(rho[p : 2 * p], y[n + 1 - p : n + 1])
            p *= 2
    return z[:L]


def main():
    rng = np.random.RandomState(0)
    L = 256
    base_y = rng.randn(L) * 0.1
    base_r = rng.randn(L) * 0.1

    # data-dependent: y_i and rho_i each perturbed by the last output
    def y_fn(i, z_hist):
        return base_y[i] + (0.01 * z_hist[-1] if len(z_hist) else 0.0)

    def rho_fn(i, z_hist):
        return base_r[i] + (0.02 * np.tanh(z_hist[-1]) if len(z_hist) else 0.0)

    z_flash = flash_data_dependent(y_fn, rho_fn, L)

    # naive online reference
    y = np.zeros(L)
    r = np.zeros(L)
    z = np.zeros(L)
    for t in range(L):
        y[t] = y_fn(t, z[:t])
        r[t] = rho_fn(t, z[:t])
        z[t] = sum(y[i] * r[t - i] for i in range(t + 1))

    err = np.abs(z_flash - z).max()
    print(f"L={L}: max |flash - naive| = {err:.2e}")
    assert err < 1e-8, "Algorithm 5 diverged from the naive online evaluation"
    print("✓ Algorithm 5 (data-dependent filters) is exact under causal reveal")


if __name__ == "__main__":
    main()
