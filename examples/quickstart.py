"""Quickstart: the paper's algorithm in ~40 lines.

Builds a small Hyena LCSM, generates tokens three ways — Flash Inference
(Algorithm 2/3), lazy, eager — checks they emit the SAME tokens (exact
inference), and prints the speed comparison.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.hyena import HyenaLCSM
from repro.serving import LCSMServer


def main():
    cfg = dataclasses.replace(
        get_config("hyena").smoke(), name="hyena-quickstart",
        n_layers=4, d_model=64, d_ff=128, vocab=512)
    params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    L = 128

    results = {}
    for strategy in ("flash", "lazy", "eager"):
        srv = LCSMServer(cfg, params, batch=1, gen_max=L, strategy=strategy)
        srv.generate(None, L)  # warm-up: full schedule compiles
        t0 = time.perf_counter()
        toks = srv.generate(None, L)
        dt = time.perf_counter() - t0
        results[strategy] = (toks, dt)
        print(f"{strategy:6s}: {L} tokens in {dt:6.2f}s "
              f"({L / dt:6.1f} tok/s)  first 10: {toks[0, :10].tolist()}")

    assert np.array_equal(results["flash"][0], results["lazy"][0])
    assert np.array_equal(results["flash"][0], results["eager"][0])
    print("\n✓ identical token streams — Flash Inference is EXACT "
          "(not an approximation like SSM distillation)")
    print(f"✓ mixer work: O(L log² L) vs Ω(L²) — "
          f"naive/flash time ratio {results['lazy'][1] / results['flash'][1]:.2f}×"
          f" at L={L} (grows with L; see benchmarks/bench_mixer.py)")


if __name__ == "__main__":
    main()
