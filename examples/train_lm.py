"""End-to-end training driver: any --arch, synthetic data, AdamW + cosine,
checkpointing.  The committed default trains a reduced Hyena LM for 200
steps on CPU; on a real TPU pod the same driver takes the full config
(drop --smoke) under repro.launch.train's production mesh.

    PYTHONPATH=src python examples/train_lm.py --arch hyena --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch falcon-mamba-7b --steps 50
"""

import argparse

import jax

from repro.configs import get_config
from repro.data import SyntheticLMDataset
from repro.optim import AdamWConfig
from repro.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hyena")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-config", action="store_true",
                    help="train the full (not reduced) architecture")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.smoke()
    print(f"training {cfg.name}: {cfg.n_layers}L d{cfg.d_model} "
          f"vocab {cfg.vocab} | {args.steps} steps x {args.batch}x{args.seq_len}")

    tr = Trainer(cfg, AdamWConfig(lr=args.lr, total_steps=args.steps,
                                  warmup_steps=max(1, args.steps // 20)))
    n_params = sum(x.size for x in jax.tree.leaves(tr.params))
    print(f"params: {n_params / 1e6:.2f}M")
    ds = SyntheticLMDataset(cfg, global_batch=args.batch, seq_len=args.seq_len,
                            n_vis=8 if cfg.m_rope else 0)
    hist = tr.fit(ds, args.steps, log_every=max(1, args.steps // 10),
                  ckpt_dir=args.ckpt_dir or None,
                  ckpt_every=args.steps if args.ckpt_dir else 0)
    print(f"\nloss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"over {args.steps} steps ({hist[-1]['wall_s']:.1f}s)")


if __name__ == "__main__":
    main()
