"""Serving example: continuous batching over a mixed request stream.

Submits requests with different prompt/output lengths to the fixed-slot
ServingEngine (2 slots, 8 requests) — slots refill as requests finish,
exactly the vLLM-style admission loop — then verifies every emitted stream
against an independent one-at-a-time greedy decode.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2.5-3b
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.lm import LM
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=args.slots, max_seq=64,
                        cache_dtype=jnp.float32)

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.n_requests):
        p_len = int(rng.randint(2, 8))
        reqs.append(Request(uid=i,
                            prompt=rng.randint(0, cfg.vocab, (p_len,)).astype(np.int32),
                            max_new=int(rng.randint(4, 10))))
        eng.submit(reqs[-1])

    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"on {args.slots} slots ({total / dt:.1f} tok/s)")

    # verify against isolated greedy decode
    for r in sorted(done, key=lambda r: r.uid):
        toks = list(r.prompt)
        for _ in range(len(r.out)):
            hidden, _ = model.forward(params, {"tokens": jnp.asarray(
                np.asarray(toks, np.int32))[None]})
            toks.append(int(jnp.argmax(model.logits(params, hidden[:, -1])[0])))
        ok = toks[len(r.prompt):] == r.out
        print(f"req {r.uid}: {len(r.prompt)}-tok prompt -> {r.out}  "
              f"{'✓' if ok else '✗ MISMATCH'}")
        assert ok
    print("✓ continuous batching is exact (per-request streams unaffected "
          "by slot sharing)")


if __name__ == "__main__":
    main()
