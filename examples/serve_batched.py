"""Serving example: continuous batching over a mixed request stream.

Submits requests with different prompt/output lengths to a fixed-slot
server (slots refill as requests finish — the vLLM-style admission loop),
then verifies every emitted stream against an independent one-at-a-time
greedy decode.  Works for both backend families through ``make_server``:

    PYTHONPATH=src python examples/serve_batched.py --arch qwen2.5-3b
    PYTHONPATH=src python examples/serve_batched.py --arch hyena
    PYTHONPATH=src python examples/serve_batched.py --arch gla --chunk 4

The hyena path routes through the Flash-Inference LCSMServer, whose tile
schedule is per-slot — each request runs its own Algorithm-2 schedule
while sharing the batched red pass and per-tile-side gray dispatches.
The gla path ("and Beyond", §4) runs the SAME per-slot schedules through
the generic-mixer engine (GenericServer).  ``--chunk K`` (LCSM/GLA)
advances slots in fused device-resident K-token chunks — one dispatch and
one token readback per chunk — and the exactness check below still holds
stream-for-stream.

``--traffic`` serves the same mixed stream through the frontend scheduler
instead (repro.serving.frontend): requests *arrive over time*, tokens are
STREAMED per request via callbacks as they are produced, repeated prompts
restore their prefix-cached post-prefill rows instead of re-prefilling,
and a latency snapshot (TTFT, queue depth, tok/s) is printed — with the
same per-stream exactness check against isolated decodes at the end:

    PYTHONPATH=src python examples/serve_batched.py --arch hyena --traffic
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.serving import Request, make_server

PROMPT_MAX, GEN_MAX = 8, 16


def _reference_decode(cfg, params, prompt, n):
    """Isolated batch-1 greedy decode of ``prompt`` for ``n`` tokens."""
    if cfg.family == "lcsm":
        from repro.serving.lcsm_backend import isolated_decode

        # same prompt_max/gen_max as the server => same Lbuf => identical
        # length-normalized implicit filters.
        return isolated_decode(cfg, params, prompt, n,
                               prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
    if cfg.family == "gla":
        from repro.serving.generic_backend import isolated_decode

        return isolated_decode(cfg, params, prompt, n,
                               prompt_max=PROMPT_MAX, gen_max=GEN_MAX)
    from repro.models.lm import LM

    model = LM(cfg)
    toks = list(prompt)
    for _ in range(n):
        hidden, _ = model.forward(params, {"tokens": jnp.asarray(
            np.asarray(toks, np.int32))[None]})
        toks.append(int(jnp.argmax(model.logits(params, hidden[:, -1])[0])))
    return toks[len(prompt):]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--chunk", type=int, default=None,
                    help="fused decode chunk size K (LCSM/GLA backends); "
                         "default: per-step")
    ap.add_argument("--traffic", action="store_true",
                    help="serve via the frontend scheduler: timed arrivals, "
                         "streamed tokens, prefix-state cache, telemetry "
                         "(LCSM/GLA archs)")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    if cfg.family == "lcsm":
        from repro.models.hyena import HyenaLCSM
        params = HyenaLCSM(cfg).init(jax.random.PRNGKey(0))
    elif cfg.family == "gla":
        from repro.models.gla import GLALM
        params = GLALM(cfg).init(jax.random.PRNGKey(0))
    else:
        from repro.models.lm import LM
        params = LM(cfg).init(jax.random.PRNGKey(0))
    eng = make_server(cfg, params, n_slots=args.slots, max_seq=64,
                      prompt_max=PROMPT_MAX, gen_max=GEN_MAX,
                      **({} if cfg.family in ("lcsm", "gla")
                         else {"cache_dtype": jnp.float32}))

    if args.traffic:
        assert cfg.family in ("lcsm", "gla"), (
            "--traffic demo uses the prefix cache (LCSM/GLA backends)")
        from repro.serving.frontend import (PrefixCache, TrafficRequest,
                                            TrafficScheduler)

        rng = np.random.RandomState(0)
        shared = rng.randint(0, cfg.vocab, (5,)).astype(np.int32)
        trace = []
        for i in range(args.n_requests):
            if rng.rand() < 0.5:   # half the traffic repeats a system prompt
                prompt = shared
            else:
                p_len = int(rng.randint(2, PROMPT_MAX))
                prompt = rng.randint(0, cfg.vocab, (p_len,)).astype(np.int32)
            trace.append(TrafficRequest(
                req=Request(uid=i, prompt=prompt,
                            max_new=int(rng.randint(4, 10))),
                arrival=float(i),  # one new request per decode step
                on_token=(lambda uid: lambda tok, j: print(
                    f"  req {uid} streamed tok[{j}] = {tok}"))(i)))
        sched = TrafficScheduler(eng, prefix_cache=PrefixCache(),
                                 chunk=args.chunk)
        t0 = time.perf_counter()
        report = sched.run(trace)
        dt = time.perf_counter() - t0
        m = report.metrics
        print(f"served {m['requests']['completed']} requests / "
              f"{m['throughput']['tokens']} tokens in {dt:.2f}s — "
              f"TTFT mean {m['ttft_s']['mean'] * 1e3:.1f} ms, "
              f"queue depth mean {m['queue_depth']['mean']:.2f}, "
              f"prefix-cache hits {report.cache['hits']}")
        for tr in sorted(trace, key=lambda tr: tr.req.uid):
            r = tr.req
            ref = _reference_decode(cfg, params, r.prompt, len(r.out))
            hit = "cache-hit " if tr.cache_hit else ""
            assert ref == r.out, f"req {r.uid}: {r.out} != {ref}"
            print(f"req {r.uid}: {hit}{len(r.prompt)}-tok prompt -> {r.out}  ✓")
        print("✓ traffic serving is exact (streams unaffected by slot "
              "sharing, arrival timing, or prefix-cache restores)")
        return

    rng = np.random.RandomState(0)
    reqs = []
    for i in range(args.n_requests):
        p_len = int(rng.randint(2, PROMPT_MAX))
        reqs.append(Request(uid=i,
                            prompt=rng.randint(0, cfg.vocab, (p_len,)).astype(np.int32),
                            max_new=int(rng.randint(4, 10))))
        eng.submit(reqs[-1])

    t0 = time.perf_counter()
    done = eng.run(chunk=args.chunk)
    dt = time.perf_counter() - t0
    total = sum(len(r.out) for r in done)
    # ServingEngine.run ignores chunk (no fused multi-token transformer
    # step) — only report it where it actually changed the decode.
    chunk_note = (f", chunk={args.chunk}"
                  if args.chunk and cfg.family in ("lcsm", "gla") else "")
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"on {args.slots} slots{chunk_note} ({total / dt:.1f} tok/s)")

    # verify against isolated greedy decode
    for r in sorted(done, key=lambda r: r.uid):
        ref = _reference_decode(cfg, params, r.prompt, len(r.out))
        ok = ref == r.out
        print(f"req {r.uid}: {len(r.prompt)}-tok prompt -> {r.out}  "
              f"{'✓' if ok else '✗ MISMATCH'}")
        assert ok
    print("✓ continuous batching is exact (per-request streams unaffected "
          "by slot sharing)")


if __name__ == "__main__":
    main()
